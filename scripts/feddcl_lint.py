#!/usr/bin/env python
"""feddcl_lint — AST lint enforcing the repo's regression-derived
invariants (repro.analysis.lint, rules R001–R008; DESIGN.md §9).

  PYTHONPATH=src python scripts/feddcl_lint.py            # human output
  PYTHONPATH=src python scripts/feddcl_lint.py --json     # machine output
  PYTHONPATH=src python scripts/feddcl_lint.py src tests  # explicit roots

Exit status: 0 clean, 1 violations found, 2 bad invocation. Deliberate
exceptions are allowlisted in-source with
`# feddcl-lint: disable=Rxxx  <justification>`.

Stdlib-only (no jax import): runs on bare CI runners before any
dependency install.
"""
from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

from repro.analysis.lint import (RULES, iter_python_files, lint_file,  # noqa: E402
                                 violations_json)

# the surfaces the invariants govern (ISSUE 9): library + every committed
# driver that feeds results/ artifacts
DEFAULT_ROOTS = ("src", "benchmarks", "experiments", "examples", "scripts")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("roots", nargs="*", default=None,
                    help=f"files/directories to lint (default: "
                         f"{' '.join(DEFAULT_ROOTS)})")
    ap.add_argument("--json", action="store_true",
                    help="machine-readable JSON on stdout")
    ap.add_argument("--rules", default=None,
                    help="comma-separated subset of rules to report "
                         "(default: all)")
    args = ap.parse_args(argv)

    repo_root = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                             os.pardir)
    roots = args.roots or [os.path.join(repo_root, r)
                           for r in DEFAULT_ROOTS
                           if os.path.exists(os.path.join(repo_root, r))]
    if not roots:
        print("feddcl_lint: no lintable roots found", file=sys.stderr)
        return 2
    only = None
    if args.rules:
        only = {r.strip().upper() for r in args.rules.split(",") if r.strip()}
        unknown = only - set(RULES)
        if unknown:
            print(f"feddcl_lint: unknown rule(s) {sorted(unknown)}; "
                  f"known: {sorted(RULES)}", file=sys.stderr)
            return 2

    files = list(iter_python_files(roots))
    violations = []
    for path in files:
        for v in lint_file(path):
            if only is None or v.rule in only:
                # report paths relative to the repo root for stable output
                v.path = os.path.relpath(v.path, repo_root) \
                    if os.path.isabs(v.path) else v.path
                violations.append(v)

    if args.json:
        print(violations_json(violations, files_checked=len(files)))
    else:
        for v in violations:
            print(v.format())
        print(f"feddcl_lint: {len(violations)} violation(s) in "
              f"{len(files)} file(s)")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
