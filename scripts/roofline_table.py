"""Aggregate results/dryrun/*.json into the EXPERIMENTS.md roofline table."""
from __future__ import annotations

import glob
import json
import os
import sys

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
ARCH_ORDER = ["llama3.2-1b", "gemma2-2b", "starcoder2-15b", "rwkv6-3b",
              "granite-moe-1b-a400m", "musicgen-large", "deepseek-v3-671b",
              "glm4-9b", "zamba2-1.2b", "chameleon-34b"]


def load(result_dir="results/dryrun", include_tagged=False):
    recs = {}
    for f in glob.glob(os.path.join(result_dir, "*.json")):
        name = os.path.basename(f)[:-5]
        if not include_tagged and name.count("__") > 3:
            continue                      # tagged hillclimb/variant record
        r = json.load(open(f))
        recs[(r["arch"], r["shape"], r["mesh"], r["mode"])] = r
    return recs


def table(recs, mesh="16x16", mode="baseline", fmt="md"):
    rows = []
    header = ("| arch | shape | kind | compute | memory | collective | "
              "dominant | useful% | mem/dev GiB | coll GB/dev |")
    sep = "|" + "---|" * 10
    rows.append(header)
    rows.append(sep)
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape, mesh, mode))
            if r is None:
                rows.append(f"| {arch} | {shape} | — | MISSING | | | | | | |")
                continue
            mem = (r["memory"]["argument_bytes"] + r["memory"]["temp_bytes"]) / 2**30
            rows.append(
                f"| {arch} | {shape} | {r['kind']} | "
                f"{r['compute_s']*1e3:.1f}ms | {r['memory_s']*1e3:.1f}ms | "
                f"{r['collective_s']*1e3:.1f}ms | {r['dominant'][:-2]} | "
                f"{r['useful_flops_ratio']*100:.1f} | {mem:.1f} | "
                f"{r['collective_bytes_per_device']/1e9:.1f} |")
    return "\n".join(rows)


def missing(recs, mesh, mode="baseline"):
    out = []
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            if (arch, shape, mesh, mode) not in recs:
                out.append((arch, shape))
    return out


if __name__ == "__main__":
    recs = load(sys.argv[1] if len(sys.argv) > 1 else "results/dryrun")
    print(f"records: {len(recs)}")
    for mesh in ("16x16", "2x16x16"):
        m = missing(recs, mesh)
        print(f"mesh {mesh}: {40 - len(m)}/40 baseline pairs done; missing: {m[:6]}")
    print()
    print(table(recs))
