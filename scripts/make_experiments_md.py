"""Generate EXPERIMENTS.md §Dry-run / §Roofline tables from results/ JSONs.
The narrative sections are maintained by hand in EXPERIMENTS.header.md; this
script concatenates header + generated tables so the document is always in
sync with the recorded runs."""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, "scripts")
from roofline_table import ARCH_ORDER, SHAPE_ORDER, load, table  # noqa: E402


def fed_table(result_dir="results/dryrun"):
    """Cross-pod (DCI-link) bytes from the boundary-classified `__xs`
    records: the paper's communication claim on the scarce link."""
    recs = {}
    for f in glob.glob(os.path.join(result_dir, "*__xs.json")):
        r = json.load(open(f))
        recs[(r["arch"], r["mode"])] = r
    rows = ["| arch (train_4k, 2×16×16) | baseline cross-pod GB/dev/step | "
            "feddcl local | feddcl sync | amortized (H=4) | DCI reduction | "
            "total coll (baseline→fed) |", "|" + "---|" * 7]
    for arch in ARCH_ORDER:
        b = recs.get((arch, "baseline"))
        l = recs.get((arch, "feddcl"))
        s = recs.get((arch, "feddcl_sync"))
        if not (b and l and s):
            continue
        bb = b["cross_silo_bytes_per_device"] / 1e9
        ll = l["cross_silo_bytes_per_device"] / 1e9
        ss = s["cross_silo_bytes_per_device"] / 1e9
        am = ll + ss / 4
        tot_b = b["collective_bytes_per_device"] / 1e9
        tot_l = l["collective_bytes_per_device"] / 1e9
        rows.append(f"| {arch} | {bb:.3f} | {ll:.3f} | {ss:.3f} | {am:.3f} "
                    f"| **{bb/max(am,1e-9):.0f}×** | {tot_b:.1f}→{tot_l:.1f} |")
    rows.append("")
    rows.append("Scan-build accounting (like-for-like both sides); the local "
                "step's cross-silo freedom is additionally asserted "
                "structurally in tests/test_federated.py (no replica group "
                "spans a silo). Intra-pod (ICI) traffic is unchanged by "
                "design — FedDCL's tiers map silos onto pods precisely so "
                "the iterative traffic stays on fast links.")
    return "\n".join(rows)


def hillclimb_table(result_dir="results/dryrun"):
    """Baseline vs tagged variant records."""
    rows = ["| pair | variant | compute | memory | collective | dominant | "
            "mem/dev GiB |", "|" + "---|" * 7]
    files = sorted(glob.glob(os.path.join(result_dir, "*__opt*.json")) +
                   glob.glob(os.path.join(result_dir, "*__base_scan.json")))
    for f in files:
        r = json.load(open(f))
        tag = os.path.basename(f).split("__")[-1][:-5]
        base = os.path.basename(f).split("__opt")[0].split("__base_scan")[0]
        base = base.rstrip("_")
        bfile = os.path.join(result_dir, base + ".json")
        if os.path.exists(bfile) and "base_scan" not in tag:
            b = json.load(open(bfile))
            rows.append(_hc_row(b, "baseline"))
        rows.append(_hc_row(r, tag))
    rows.append("")
    rows.append("(`opt_rwkvseq_scan` compares against `base_scan` — both "
                "scan-build, like-for-like; `opt_expandkv` is the RETAINED "
                "REFUTED iteration, superseded by `opt_cacheseq`. Narrative "
                "below.)")
    return "\n".join(rows)


def _hc_row(r, label):
    mem = (r["memory"]["argument_bytes"] + r["memory"]["temp_bytes"]) / 2**30
    return (f"| {r['arch']}×{r['shape']} | {label} | "
            f"{r['compute_s']*1e3:.1f}ms | {r['memory_s']*1e3:.1f}ms | "
            f"{r['collective_s']*1e3:.1f}ms | {r['dominant'][:-2]} | {mem:.1f} |")


def main():
    recs = load("results/dryrun")
    parts = [open("EXPERIMENTS.header.md").read()]

    n16 = sum(1 for k in recs if k[2] == "16x16" and k[3] == "baseline")
    n32 = sum(1 for k in recs if k[2] == "2x16x16" and k[3] == "baseline")
    parts.append(f"\n## §Dry-run — compile status\n\n"
                 f"Baseline pairs compiled: **{n16}/40** on 16×16 (256 chips), "
                 f"**{n32}/40** on 2×16×16 (512 chips). Per-pair JSON records "
                 f"(memory_analysis, cost_analysis, collective breakdown) in "
                 f"`results/dryrun/`.\n")

    parts.append("\n## §Roofline — single-pod (16×16, 256 chips) baseline\n\n"
                 "Terms per step per chip (seconds→ms; constants: 197 TFLOP/s "
                 "bf16, 819 GB/s HBM, 50 GB/s/link):\n\n")
    parts.append(table(recs, mesh="16x16", mode="baseline"))

    parts.append("\n\n### Multi-pod (2×16×16, 512 chips) compile proof\n\n"
                 "All pairs lower+compile; cost columns are scan-build values "
                 "(while-loop bodies counted once — compile proof + memory "
                 "only, see Methodology):\n\n")
    parts.append(table(recs, mesh="2x16x16", mode="baseline"))

    parts.append("\n\n## §Perf — FedDCL communication schedule (the paper's "
                 "technique at mesh level)\n\n")
    parts.append(fed_table())

    parts.append("\n\n### Hillclimb records (baseline → optimized)\n\n")
    parts.append(hillclimb_table())

    if os.path.exists("EXPERIMENTS.perflog.md"):
        parts.append("\n\n" + open("EXPERIMENTS.perflog.md").read())

    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(parts) + "\n")
    print("EXPERIMENTS.md written")


if __name__ == "__main__":
    main()
