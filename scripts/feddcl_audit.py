#!/usr/bin/env python
"""feddcl_audit — compiled-artifact smoke audit (repro.analysis.hlo_audit;
DESIGN.md §9): lower a tiny FL plan in EVERY flavor and assert

  1. no baked tenant data: the StableHLO holds no large non-splat
     constant (the PR 3 artifact-level privacy leak), for
     {vmap, sharded} × {weighted, robust} × {whole-phase, chunked};
  2. collective census: unsharded plans contain ZERO collectives; sharded
     weighted plans exactly {all-reduce: leaves+1} per hierarchy level;
     sharded robust plans {all-reduce: 1, all-gather: leaves+1};
  3. the positive control: a deliberately closure-baked plan (data
     captured instead of passed) FAILS the audit — the check can actually
     see the leak it guards against;
  4. CompileCounter: a second identical plan invocation performs zero
     backend compilations.

  PYTHONPATH=src python scripts/feddcl_audit.py [--devices N] [--json]

Exit status: 0 all invariants hold, 1 otherwise. Run by the CI `lint`
job next to scripts/feddcl_lint.py.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--devices", type=int, default=8,
                    help="virtual CPU devices to force (default 8, so the "
                         "sharded flavors really shard; must be set before "
                         "jax initializes)")
    ap.add_argument("--min-elems", type=int, default=512,
                    help="baked-constant threshold in elements")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    if "jax" not in sys.modules and args.devices > 1:
        flags = os.environ.get("XLA_FLAGS", "")
        if "host_platform_device_count" not in flags:
            os.environ["XLA_FLAGS"] = (
                f"{flags} --xla_force_host_platform_device_count="
                f"{args.devices}").strip()
    sys.path.insert(0, os.path.join(
        os.path.dirname(os.path.abspath(__file__)), os.pardir, "src"))

    import jax
    import numpy as np

    from repro.analysis.hlo_audit import (BakedDataError, CompileCounter,
                                          assert_no_baked_data,
                                          collective_census)
    from repro.core import federated
    from repro.core.federated import lower_fl_plan, make_fl_plan, pad_silo_data
    from repro.launch.mesh import make_host_mesh
    from repro.models import mlp
    from repro.optim import adamw

    # sized so every padded tensor (and the closure-captured control slice)
    # clears --min-elems: 3 silos x 7 batches x 8 x 16 features
    rng = np.random.default_rng(0)
    feat = 16
    w_true = rng.standard_normal((feat, 1))
    silos = []
    for n in (56, 49, 52):
        X = rng.standard_normal((n, feat))
        silos.append((X, X @ w_true + 0.01 * rng.standard_normal((n, 1))))
    params = mlp.init_mlp_params(jax.random.PRNGKey(0), feat, (8,), 1)
    loss = lambda p, x, y: mlp.mlp_per_example_loss(p, x, y, "regression")
    batch_loss = federated._make_batch_loss(loss, True, 0.0)
    leaves = len(jax.tree_util.tree_leaves(params))
    mesh = make_host_mesh(model=1) if jax.device_count() > 1 else None
    shards = federated.num_silo_shards(mesh) if mesh is not None else 1

    report = {"devices": jax.device_count(), "flavors": [], "ok": True}

    def check(name, *, mesh, aggregator, collect):
        padded = pad_silo_data(silos, 8,
                               min_silos=-(-len(silos) // shards) * shards
                               if mesh is not None else 0)
        plan = make_fl_plan(
            num_silos=padded.num_silos, num_batches=padded.num_batches,
            batch_size=padded.batch_size, opt=adamw(1e-2),
            batch_loss=batch_loss, rounds=2, local_epochs=2,
            aggregator=aggregator, masked=True, collect=collect, mesh=mesh)
        lowered = lower_fl_plan(plan, params, padded, rounds=2)
        assert_no_baked_data(lowered, min_elems=args.min_elems)
        census = collective_census(lowered)
        row = {"flavor": name, "baked": 0, "collectives": census}
        if mesh is None:
            assert census == {}, (
                f"{name}: unsharded plan must hold no collective, "
                f"got {census}")
        elif aggregator in federated.ROBUST_AGGREGATORS:
            assert census == {"all-reduce": 1, "all-gather": leaves + 1}, (
                name, census)
        else:
            assert census == {"all-reduce": leaves + 1}, (name, census)
        report["flavors"].append(row)
        return plan, padded

    # flavor matrix: {vmap, sharded} × {weighted, robust} × {phase, chunk}
    plan, padded = check("vmap/fedavg/whole", mesh=None,
                         aggregator="fedavg", collect="none")
    check("vmap/median/whole", mesh=None, aggregator="median",
          collect="none")
    check("vmap/fedavg/chunk", mesh=None, aggregator="fedavg",
          collect="chunk")
    if mesh is not None:
        check("sharded/fedavg/whole", mesh=mesh, aggregator="fedavg",
              collect="none")
        check("sharded/trimmed_mean/whole", mesh=mesh,
              aggregator="trimmed_mean", collect="none")
        check("sharded/fedavg/chunk", mesh=mesh, aggregator="fedavg",
              collect="chunk")

    # positive control: a closure-baked "plan" must FAIL the audit
    import jax.numpy as jnp
    baked_X = jnp.asarray(padded.X)                     # captured, not passed
    # feddcl-lint: disable=R004  deliberate: this IS the leak the control verifies the audit can see
    leaky = jax.jit(lambda p: batch_loss(
        p, baked_X[0], jnp.asarray(padded.Y)[0],
        jnp.asarray(padded.w)[0], p))
    try:
        assert_no_baked_data(leaky.lower(params),
                             min_elems=args.min_elems)
    except BakedDataError:
        report["positive_control"] = "caught"
    else:
        report["ok"] = False
        report["positive_control"] = "MISSED"
        raise SystemExit(
            "closure-baked control passed the audit — assert_no_baked_data "
            "cannot see the leak it guards against")

    # recompile sentinel: an identical second invocation compiles nothing
    fl_args = federated._plan_args(padded, 0, 2)
    jax.block_until_ready(plan(params, *fl_args))        # compile once
    with CompileCounter() as cc:
        jax.block_until_ready(plan(params, *fl_args))
    report["warm_recompiles"] = cc.count
    assert cc.count == 0, f"warm plan invocation compiled {cc.count} modules"

    if args.json:
        print(json.dumps(report, indent=1))
    else:
        for row in report["flavors"]:
            print(f"AUDIT_OK {row['flavor']:28s} baked=0 "
                  f"collectives={row['collectives']}")
        print(f"POSITIVE_CONTROL {report['positive_control']}")
        print(f"WARM_RECOMPILES {report['warm_recompiles']}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
