"""Batched serving of a small model with continuous-batching-lite slots.

  PYTHONPATH=src python examples/serve_batched.py --arch gemma2-2b
"""
from repro.launch.serve import main

if __name__ == "__main__":
    main()
