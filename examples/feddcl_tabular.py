"""Five-method comparison on one tabular dataset (paper Experiment II, one
column of Fig. 5): Centralized / Local / FedAvg / DC / FedDCL.

  PYTHONPATH=src python examples/feddcl_tabular.py --dataset human_activity
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.feddcl_mlp import PAPER_MLPS
from repro.core import baselines, protocol
from repro.core.federated import run_federated
from repro.data.partition import split_iid
from repro.data.tabular import make_dataset, train_test_split
from repro.models import mlp
from repro.optim import adamw


def evaluate(params, X, Y, task):
    return mlp.mlp_metric(params, jnp.asarray(X), jnp.asarray(Y), task)


def run(dataset: str, d: int = 5, c: int = 4, n_ij: int = 100, seed: int = 0,
        engine: str = "host"):
    cfg = PAPER_MLPS[dataset]
    n_train = d * c * n_ij
    ds = make_dataset(dataset, n=n_train + 1200, seed=seed)
    (Xtr, Ytr), (Xte, Yte) = train_test_split(ds, n_train, 1000, seed=seed)
    Xs, Ys = split_iid(Xtr, Ytr, d=d, c=[c] * d, n_ij=n_ij, seed=seed)
    task = cfg.task
    key = jax.random.PRNGKey(seed)
    # per-example losses let the ONE federated engine mask ragged/padded
    # silos (core/federated.py); engine='scan' compiles each trainer run
    # into a single dispatch
    loss = lambda p, x, y: mlp.mlp_per_example_loss(p, x, y, task)
    results = {}

    # Centralized (shares raw data; upper baseline)
    p = mlp.for_config(key, cfg, reduced=False)
    p, _ = baselines.sgd_train(loss, p, Xtr, Ytr, opt=adamw(1e-3), epochs=40,
                               engine=engine)
    results["Centralized"] = evaluate(p, Xte, Yte, task)

    # Local (single institution)
    p = mlp.for_config(key, cfg, reduced=False)
    p, _ = baselines.sgd_train(loss, p, Xs[0][0], Ys[0][0], opt=adamw(1e-3),
                               epochs=40, engine=engine)
    results["Local"] = evaluate(p, Xte, Yte, task)

    # FedAvg over all c·d institutions on raw features
    p = mlp.for_config(key, cfg, reduced=False)
    flat = [(Xs[i][j], Ys[i][j]) for i in range(d) for j in range(len(Xs[i]))]
    res = run_federated(loss, p, flat, opt=adamw(1e-3), rounds=20,
                        local_epochs=4, engine=engine)
    results["FedAvg"] = evaluate(res.params, Xte, Yte, task)

    # DC (conventional single-server data collaboration)
    flatX = [Xs[i][j] for i in range(d) for j in range(len(Xs[i]))]
    flatY = [Ys[i][j] for i in range(d) for j in range(len(Xs[i]))]
    maps, Gs, collabX = baselines.dc_setup(flatX, m_tilde=cfg.reduced_dim,
                                           seed=seed)
    p = mlp.for_config(key, cfg, reduced=True)
    p, _ = baselines.sgd_train(loss, p, np.concatenate(collabX),
                               np.concatenate(flatY), opt=adamw(1e-3), epochs=40,
                               engine=engine)
    results["DC"] = evaluate(p, np.asarray(maps[0](Xte) @ Gs[0]), Yte, task)

    # FedDCL (this paper)
    setup = protocol.run_protocol(Xs, Ys, m_tilde=cfg.reduced_dim, seed=seed)
    p = mlp.for_config(key, cfg, reduced=True)
    res = run_federated(loss, p, setup.fed_silos(),
                        opt=adamw(1e-3), rounds=20, local_epochs=4,
                        engine=engine)
    tr = setup.user_transform(0, 0)
    results["FedDCL"] = evaluate(res.params, np.asarray(tr(Xte)), Yte, task)

    metric = "RMSE" if task == "regression" else "Accuracy"
    print(f"\n{dataset} ({metric}):")
    for k, v in results.items():
        print(f"  {k:12s} {v:.4f}")
    return results


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="battery_small",
                    choices=sorted(PAPER_MLPS))
    ap.add_argument("--engine", default="host", choices=["host", "scan"])
    args = ap.parse_args()
    run(args.dataset, engine=args.engine)
