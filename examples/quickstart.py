"""Quickstart: the full FedDCL protocol (Algorithm 1) on a BatterySmall-like
synthetic regression task — 4 user institutions in 2 groups, exactly the
paper's Experiment I layout. Runs in ~10 s on CPU.

  PYTHONPATH=src python examples/quickstart.py
  FEDDCL_BACKEND=device PYTHONPATH=src python examples/quickstart.py

FEDDCL_BACKEND selects the step-3 collaboration backend: "host" (serial
NumPy float64, default) or "device" (batched jitted Gram+eigh and QR —
DESIGN.md §3). FEDDCL_ENGINE selects the step-4 federated engine: "host"
(per-batch dispatch reference) or "scan" (the whole FL phase as one
compiled lax.scan program — DESIGN.md §4).
"""
import os

import numpy as np

from repro.configs.feddcl_mlp import PAPER_MLPS
from repro.core import protocol
from repro.core.federated import run_federated
from repro.data.partition import split_iid
from repro.data.tabular import make_dataset, train_test_split
from repro.models import mlp
from repro.optim import adamw

import jax
import jax.numpy as jnp


def main():
    # ---- data: paper Exp I — d=2 groups, c_i=2 users, n_ij=100 ----------
    cfg = PAPER_MLPS["battery_small"]
    ds = make_dataset("battery_small", n=1500, seed=0)
    (Xtr, Ytr), (Xte, Yte) = train_test_split(ds, 400, 1000, seed=0)
    Xs, Ys = split_iid(Xtr, Ytr, d=2, c=[2, 2], n_ij=100, seed=0)

    # ---- FedDCL steps 1-3: anchor, private maps, SVD alignment ----------
    backend = os.environ.get("FEDDCL_BACKEND", "host")
    setup = protocol.run_protocol(Xs, Ys, m_tilde=cfg.reduced_dim,
                                  anchor_r=2000, seed=0,
                                  svd_backend=backend)
    print(f"collab backend: {backend} | anchor:", setup.anchor.shape,
          "| collab reps per group:", [x.shape for x in setup.collab_X])

    # ---- FedDCL step 4: FedAvg between the intra-group DC servers -------
    # per-example loss lets the engine zero-pad + mask ragged silos;
    # FEDDCL_ENGINE=scan compiles all 20 rounds into ONE device dispatch
    params = mlp.for_config(jax.random.PRNGKey(0), cfg, reduced=True)
    loss = lambda p, x, y: mlp.mlp_per_example_loss(p, x, y, cfg.task)
    engine = os.environ.get("FEDDCL_ENGINE", "host")
    res = run_federated(
        loss, params, setup.fed_silos(),
        opt=adamw(1e-3), rounds=20, local_epochs=4, batch_size=32,
        engine=engine)

    # ---- step 5: per-user integrated model t(X) = h(f(X) G) -------------
    h = lambda Z: mlp.mlp_forward(res.params, jnp.asarray(Z))
    models = protocol.finalize_user_models(setup, h)
    t00 = models[0][0]
    pred = np.asarray(t00(Xte))
    rmse = float(np.sqrt(np.mean((pred - Yte) ** 2)))
    print(f"FedDCL test RMSE: {rmse:.4f}")

    # ---- the paper's headline communication property --------------------
    trips = setup.comm.user_round_trips()
    print("cross-institution communications per user:", trips)
    assert all(v == 2 for v in trips.values()), \
        "exactly 2 per user: one upload (step 4) + one download (step 15)"
    print("== exactly 2 per user, as the paper claims (Algorithm 1)")


if __name__ == "__main__":
    main()
