"""FedDCL's outer tier applied to LLM pretraining: 4 silos (DC-server
groups), H=4 local steps per FedAvg round, reduced llama backbone, synthetic
non-IID token streams — the paper's communication schedule as a first-class
training feature (DESIGN.md §3).

  PYTHONPATH=src python examples/feddcl_llm_pretrain.py --steps 80
"""
import argparse

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--steps", type=int, default=80)
    ap.add_argument("--silos", type=int, default=4)
    ap.add_argument("--local-steps", type=int, default=4)
    args = ap.parse_args()

    _, hist = train(args.arch, reduced=True, steps=args.steps, batch=8,
                    seq=128, silos=args.silos, local_steps=args.local_steps,
                    non_iid=True, log_path="results/feddcl_llm_pretrain.json")
    first, last = hist[0]["loss"], hist[-1]["loss"]
    print(f"\nloss {first:.3f} -> {last:.3f} over {args.steps} federated steps "
          f"({args.silos} silos, sync every {args.local_steps})")
    assert last < first, "federated training should reduce loss"


if __name__ == "__main__":
    main()
