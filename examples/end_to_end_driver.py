"""End-to-end driver: train a ~100M-parameter llama-family model for a few
hundred steps on the synthetic pipeline under the FedDCL federated schedule
(2 silos, H=4) and write the loss curve to results/e2e_driver.json.

~100M config: 8 layers, d_model 512, 8 heads (kv 4), d_ff 2048, vocab 32768.
On this CPU container a full run takes tens of minutes; --steps trims it.

  PYTHONPATH=src python examples/end_to_end_driver.py --steps 200
"""
import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch
from repro.configs.base import FederatedConfig, InputShape, TrainConfig
from repro.core.federated import silo_replicate
from repro.data.tokens import silo_batches
from repro.launch import steps as steps_lib
from repro.models import backbone as bb


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--silos", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--out", default="results/e2e_driver.json")
    args = ap.parse_args()

    cfg = get_arch("llama3.2-1b").with_overrides(
        name="llama-100m", num_layers=8, d_model=512, num_heads=8,
        num_kv_heads=4, head_dim=64, d_ff=2048, vocab_size=32768)
    shape = InputShape("e2e", seq_len=args.seq, global_batch=args.batch,
                       kind="train")
    tc = TrainConfig(model=cfg, shape=shape, learning_rate=1e-3,
                     warmup_steps=20, total_steps=args.steps,
                     param_dtype="float32", compute_dtype="float32",
                     remat=False,
                     federated=FederatedConfig(num_silos=args.silos,
                                               local_steps=args.local_steps))

    params = bb.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    print(f"params: {bb.count_params_analytic(cfg)/1e6:.1f}M")
    vstep, opt = steps_lib.make_federated_local_step(cfg, tc)
    sync = steps_lib.make_fedavg_sync_step(tc)
    vstep = jax.jit(vstep, donate_argnums=(0, 1))
    sync = jax.jit(sync, donate_argnums=(0, 1))

    sp = silo_replicate(params, args.silos)
    so = jax.vmap(opt.init)(sp)
    hist = []
    t0 = time.perf_counter()
    for step in range(args.steps):
        nb = silo_batches(cfg.vocab_size, args.seq, args.batch // args.silos,
                          args.silos, step, non_iid=True)
        b = {k: jnp.asarray(v) for k, v in nb.items()}
        sp, so, m = vstep(sp, so, b)
        if (step + 1) % args.local_steps == 0:
            sp, so = sync(sp, so)
        if step % 10 == 0 or step == args.steps - 1:
            loss = float(jnp.mean(m["loss"]))
            hist.append({"step": step, "loss": loss,
                         "elapsed_s": time.perf_counter() - t0})
            print(f"step {step:4d} loss {loss:.4f} ({hist[-1]['elapsed_s']:.0f}s)")

    import os
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump({"config": "llama-100m", "history": hist}, f, indent=1)
    print(f"-> {args.out}: loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")


if __name__ == "__main__":
    main()
