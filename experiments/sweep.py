"""Sweep driver: many FedDCL configs through ONE plan cache.

This is the canonical loop for sweep / many-tenant traffic (it replaces the
ad-hoc per-benchmark loops that previously lived only as untracked
prototypes — see ROADMAP "compiled-plan cache" item): every config runs the
full pipeline via the public ``FedDCL.fit()`` API with the shared plan
cache, so configs whose padded shapes land in the same bucket reuse one
compiled executable and the 2nd–Nth calls cost milliseconds.

Two committed artifacts (regenerate with this script):

  results/BENCH_sweep.json      cold pass vs warm pass over the 6-config
                                sweep; executables (= cache misses) strictly
                                fewer than configs
  results/BENCH_api_cache.json  one config's fit() called N times: first
                                call pays trace+compile, the rest hit

The script ASSERTS the cache invariants (fewer executables than configs,
warm speedup floor), so CI running ``--fast`` fails on a cache regression
instead of waiting for someone to re-run a benchmark by hand.

  PYTHONPATH=src:. python experiments/sweep.py [--fast] [--out-dir results]

Set FEDDCL_COMPILATION_CACHE=<dir> to also persist XLA executables across
processes (CI does; see repro.api.enable_persistent_compilation_cache).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time
from typing import Callable, Dict, List, Optional

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

import numpy as np


def run_sweep(cases: List[Dict], run_case: Callable[[Dict], Dict], *,
              label: str = "sweep", out_path: Optional[str] = None,
              verbose: bool = True) -> List[Dict]:
    """Generic timed config-grid loop: run `run_case` on each case dict,
    recording wall time per case. Returns rows = case ∪ result ∪ {time_s};
    writes them as JSON when out_path is given. Benchmarks (exp3_groups)
    and the FedDCL sweep below share this loop instead of each rolling
    their own."""
    rows = []
    for case in cases:
        t0 = time.perf_counter()
        res = run_case(case)
        dt = time.perf_counter() - t0
        row = {**case, **(res or {}), "time_s": round(dt, 4)}
        rows.append(row)
        if verbose:
            desc = " ".join(f"{k}={v}" for k, v in case.items())
            print(f"[{label}] {desc}  ({dt:.3f}s)")
    if out_path:
        os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
        with open(out_path, "w") as f:
            json.dump(rows, f, indent=1)
        if verbose:
            print(f"[{label}] -> {out_path}")
    return rows


# --------------------------------------------------------------------------
# The FedDCL 6-config sweep (BENCH_sweep) + api-cache bench (BENCH_api_cache)
# --------------------------------------------------------------------------

M_FEAT = 16          # raw feature dim m
M_TILDE = 8          # intermediate dim m̃ = m̂
ANCHOR_R = 512


def _make_groups(d: int, c: int, n_ij: int, seed: int = 0):
    """Synthetic (Xs, Ys) in the protocol layout: group i, user j."""
    r = np.random.default_rng(seed)
    w = r.standard_normal((M_FEAT, 1))
    Xs, Ys = [], []
    for i in range(d):
        gx, gy = [], []
        for j in range(c):
            X = r.standard_normal((n_ij, M_FEAT))
            gx.append(X)
            gy.append(X @ w + 0.05 * r.standard_normal((n_ij, 1)))
        Xs.append(gx)
        Ys.append(gy)
    return Xs, Ys


def sweep_configs(fast: bool = False) -> List[Dict]:
    """Six tenant configs spanning three shape buckets — two configs per
    (silo-bucket, batch-bucket) pair, so the cache must land 3 executables
    and 3 hits on the cold pass (and 6 hits warm)."""
    if fast:
        return [dict(d=2, c=2, n_ij=40, seed=0), dict(d=2, c=2, n_ij=34, seed=1),
                dict(d=3, c=2, n_ij=40, seed=2), dict(d=4, c=2, n_ij=34, seed=3)]
    return [dict(d=2, c=2, n_ij=60, seed=0), dict(d=2, c=2, n_ij=50, seed=1),
            dict(d=3, c=2, n_ij=60, seed=2), dict(d=4, c=2, n_ij=50, seed=3),
            dict(d=6, c=2, n_ij=50, seed=4), dict(d=8, c=2, n_ij=40, seed=5)]


def _fit_case(case: Dict, rounds: int, local_epochs: int) -> Dict:
    from repro.api import FedDCL

    Xs, Ys = _make_groups(case["d"], case["c"], case["n_ij"], case["seed"])
    model = FedDCL(m_tilde=M_TILDE, anchor_r=ANCHOR_R, rounds=rounds,
                   local_epochs=local_epochs, seed=case["seed"])
    t0 = time.perf_counter()
    _, res = model.fit(Xs, Ys)
    fit_s = time.perf_counter() - t0
    return {"fit_s": round(fit_s, 4), "hit": res.cache_stats["hit"],
            "final_loss": res.history[-1]["loss"],
            "score": model.score(Xs[0][0], Ys[0][0])}


def bench_sweep(fast: bool = False) -> Dict:
    from repro.core.federated import default_plan_cache

    rounds, epochs = (4, 2) if fast else (15, 4)
    cases = sweep_configs(fast)
    cache = default_plan_cache()
    cache.clear()

    cold = run_sweep(cases, lambda c: _fit_case(c, rounds, epochs),
                     label="sweep:cold")
    cold_stats = cache.stats()
    warm = run_sweep(cases, lambda c: _fit_case(c, rounds, epochs),
                     label="sweep:warm")
    warm_stats = cache.stats()

    t_cold = sum(r["fit_s"] for r in cold)
    t_warm = sum(r["fit_s"] for r in warm)
    out = {
        "bench": "feddcl_api_sweep",
        "configs": len(cases),
        "rounds": rounds, "local_epochs": epochs,
        "executables": cold_stats["misses"],
        "cold_pass": cold, "warm_pass": warm,
        "t_cold_total_s": round(t_cold, 4),
        "t_warm_total_s": round(t_warm, 4),
        "speedup": round(t_cold / max(t_warm, 1e-9), 1),
        "cache_cold": cold_stats, "cache_warm": warm_stats,
    }
    # cache invariants — a regression here should fail CI, not linger in an
    # unregenerated benchmark artifact
    assert cold_stats["misses"] < len(cases), \
        f"bucketing broken: {cold_stats['misses']} executables for {len(cases)} configs"
    assert all(r["hit"] for r in warm), "warm pass missed the plan cache"
    floor = 3.0 if fast else 20.0
    assert out["speedup"] >= floor, \
        f"warm sweep only {out['speedup']}x over cold (floor {floor}x)"
    print(f"[sweep] {len(cases)} configs -> {out['executables']} executables; "
          f"cold {t_cold:.2f}s warm {t_warm:.3f}s ({out['speedup']}x)")
    return out


def bench_api_cache(fast: bool = False) -> Dict:
    """One shape bucket, N fresh fit() calls: call 1 pays trace+compile,
    calls 2..N cost milliseconds — the sklearn-API amortization claim."""
    from repro.core.federated import default_plan_cache

    rounds, epochs = (4, 2) if fast else (15, 4)
    n_calls = 4 if fast else 6
    default_plan_cache().clear()
    calls = []
    for k in range(n_calls):
        case = dict(d=3, c=2, n_ij=50 + 2 * k, seed=k)   # same bucket, new tenant
        calls.append({**case, **_fit_case(case, rounds, epochs)})
        print(f"[api-cache] call {k}: {calls[-1]['fit_s']:.4f}s "
              f"hit={calls[-1]['hit']}")
    t_first = calls[0]["fit_s"]
    t_rest = [c["fit_s"] for c in calls[1:]]
    out = {
        "bench": "feddcl_api_cache",
        "calls": calls,
        "t_first_s": round(t_first, 4),
        "t_warm_mean_s": round(float(np.mean(t_rest)), 4),
        "speedup": round(t_first / max(float(np.mean(t_rest)), 1e-9), 1),
        "cache": default_plan_cache().stats(),
    }
    assert not calls[0]["hit"] and all(c["hit"] for c in calls[1:]), \
        "api-cache: expected exactly one miss then all hits"
    floor = 3.0 if fast else 20.0
    assert out["speedup"] >= floor, \
        f"warm fit() only {out['speedup']}x over cold (floor {floor}x)"
    print(f"[api-cache] first {t_first:.3f}s, warm mean "
          f"{out['t_warm_mean_s']*1000:.1f}ms ({out['speedup']}x)")
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI smoke grid")
    ap.add_argument("--out-dir", default="results")
    args = ap.parse_args()

    from repro.api import enable_persistent_compilation_cache
    cc = enable_persistent_compilation_cache()
    if cc:
        print(f"[sweep] persistent XLA compilation cache: {cc}")

    import jax
    meta = {"platform": jax.default_backend(), "jax": jax.__version__,
            "fast": args.fast}
    os.makedirs(args.out_dir, exist_ok=True)
    for name, bench in (("BENCH_sweep", bench_sweep),
                        ("BENCH_api_cache", bench_api_cache)):
        out = {**meta, **bench(fast=args.fast)}
        path = os.path.join(args.out_dir, f"{name}.json")
        with open(path, "w") as f:
            json.dump(out, f, indent=1)
        print(f"-> {path}")


if __name__ == "__main__":
    main()
