"""Reproducible experiment drivers (committed, unlike the untracked
prototypes they replace): config-grid sweeps through the public
FedDCL.fit() API and the compiled-plan cache."""
