"""Hostile-world ablation driver (DESIGN.md §8): fedavg vs the robust
aggregators under active attack and silo dropout.

Grid: d=6 ragged regression silos × {clean, 1 or 2 gradient-scaling silos
(scale=−5, the sign-flip attacker), 1 label-flipping silo} ×
{fedavg, median, trimmed_mean, krum}, each with its per-round loss curve
and the final global model's loss on the HONEST silos' pooled data (the
reported round loss averages in the corrupted silo's own objective, which
under label-flip hides the damage).

Committed artifact (regenerate with this script):

  results/BENCH_fed_robust.json   loss curves + honest-data final losses
                                  for every (attack, aggregator) cell, the
                                  dropout rows, and the engine/sharding
                                  agreement numbers

The script ASSERTS the §8 acceptance criteria, so CI running ``--fast``
fails on a robustness regression instead of waiting for a human to re-read
a benchmark table:

  * under ≥1 gradient-scaling silo, at least one robust aggregator reaches
    a final loss ≤ 0.5× plain fedavg's (it also must not be much worse
    than the clean-run reference);
  * host == scan ≤ 1e-4 for every robust aggregator on the ragged grid,
    dropout included;
  * sharded (8 virtual devices, subprocess) == unsharded ≤ 1e-4 for every
    robust aggregator under dropout + a scaled silo.

  PYTHONPATH=src:. python experiments/robust_ablation.py [--fast]
                                                         [--out-dir results]
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import textwrap
import time
from typing import Dict, List, Optional, Tuple

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                os.pardir, "src"))

import numpy as np

AGGREGATORS = ("fedavg", "median", "trimmed_mean", "krum")
TRIM_FRAC = 0.25          # d=6: trims floor(6·0.25)=1 silo per tail
KRUM_F = 2                # tolerate up to 2 Byzantine silos


def make_silos(sizes, m=4, seed=0) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Ragged linear-regression silos sharing one true w (the honest
    signal every attacker tries to bury)."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, 1))
    out = []
    for k, n in enumerate(sizes):
        r = np.random.default_rng(seed * 97 + k + 1)
        X = r.standard_normal((n, m))
        out.append((X, X @ w + 0.01 * r.standard_normal((n, 1))))
    return out


def scenarios(d: int):
    from repro.core.privacy import SiloAttack
    return [
        ("clean", SiloAttack()),
        ("grad_scale_x1", SiloAttack(corrupted=(2,), kind="grad_scale",
                                     scale=-5.0)),
        ("grad_scale_x2", SiloAttack(corrupted=(1, 4), kind="grad_scale",
                                     scale=-5.0)),
        ("label_flip_x1", SiloAttack(corrupted=(3,), kind="label_flip")),
    ]


def run_grid(sizes, rounds: int, epochs: int, *, seed: int = 17,
             dropout_rate: float = 0.0) -> List[Dict]:
    import jax
    import jax.numpy as jnp
    from repro.core.federated import run_federated
    from repro.core.privacy import apply_attack
    from repro.models import mlp
    from repro.optim import adamw

    loss = lambda p, x, y: mlp.mlp_per_example_loss(p, x, y, "regression")
    silos = make_silos(sizes, seed=9)
    params = mlp.init_mlp_params(jax.random.PRNGKey(4), 4, (8,), 1)

    def honest_loss(p, attack):
        bad = set(attack.corrupted)
        Xh = jnp.asarray(np.concatenate(
            [x for i, (x, _) in enumerate(silos) if i not in bad]),
            jnp.float32)
        Yh = jnp.asarray(np.concatenate(
            [y for i, (_, y) in enumerate(silos) if i not in bad]),
            jnp.float32)
        return float(jnp.mean(loss(p, Xh, Yh)))

    rows = []
    for name, attack in scenarios(len(sizes)):
        data, scale = apply_attack(silos, attack)
        for agg in AGGREGATORS:
            t0 = time.perf_counter()
            res = run_federated(
                loss, params, data, opt=adamw(1e-2), rounds=rounds,
                local_epochs=epochs, batch_size=16, aggregator=agg,
                seed=seed, engine="scan", silo_scale=scale,
                dropout_rate=dropout_rate,
                trim_frac=TRIM_FRAC, krum_f=KRUM_F)
            row = {
                "scenario": name, "aggregator": agg,
                "dropout_rate": dropout_rate,
                "corrupted": list(attack.corrupted),
                "final_loss": round(res.history[-1]["loss"], 6),
                "honest_loss": round(honest_loss(res.params, attack), 6),
                "loss_curve": [round(h["loss"], 6) for h in res.history],
                "time_s": round(time.perf_counter() - t0, 4),
            }
            rows.append(row)
            print(f"[{name:>14s}] {agg:<13s} dropout={dropout_rate:.2f} "
                  f"final={row['final_loss']:.4f} "
                  f"honest={row['honest_loss']:.4f}")
    return rows


def check_engine_agreement(sizes, rounds: int, epochs: int) -> Dict[str, float]:
    """host == scan ≤1e-4 for every robust aggregator on the ragged grid,
    with dropout and one scaled silo riding along."""
    import jax
    from repro.core.federated import ROBUST_AGGREGATORS, run_federated
    from repro.models import mlp
    from repro.optim import adamw

    loss = lambda p, x, y: mlp.mlp_per_example_loss(p, x, y, "regression")
    silos = make_silos(sizes, seed=9)
    params = mlp.init_mlp_params(jax.random.PRNGKey(4), 4, (8,), 1)
    scale = [1.0] * len(sizes)
    scale[1] = -5.0
    out = {}
    for agg in ROBUST_AGGREGATORS:
        kw = dict(opt=adamw(1e-2), rounds=rounds, local_epochs=epochs,
                  batch_size=16, aggregator=agg, seed=23,
                  dropout_rate=0.3, silo_scale=scale,
                  trim_frac=TRIM_FRAC, krum_f=KRUM_F)
        host = run_federated(loss, params, silos, engine="host", **kw)
        scan = run_federated(loss, params, silos, engine="scan", **kw)
        diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                   for a, b in zip(jax.tree_util.tree_leaves(host.params),
                                   jax.tree_util.tree_leaves(scan.params)))
        assert diff <= 1e-4, f"host/scan disagree for {agg}: {diff}"
        out[agg] = diff
        print(f"[engines] {agg:<13s} host==scan diff {diff:.2e}")
    return out


SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, sys
    import jax
    import numpy as np
    from repro.core.federated import ROBUST_AGGREGATORS, run_federated
    from repro.launch.mesh import make_host_mesh
    from repro.models import mlp
    from repro.optim import adamw

    assert jax.device_count() == 8
    sizes = json.loads(sys.argv[1])
    rounds, epochs = int(sys.argv[2]), int(sys.argv[3])

    def make_silos(sizes, m=4, seed=0):
        rng = np.random.default_rng(seed)
        w = rng.standard_normal((m, 1))
        out = []
        for k, n in enumerate(sizes):
            r = np.random.default_rng(seed * 97 + k + 1)
            X = r.standard_normal((n, m))
            out.append((X, X @ w + 0.01 * r.standard_normal((n, 1))))
        return out

    loss = lambda p, x, y: mlp.mlp_per_example_loss(p, x, y, "regression")
    silos = make_silos(sizes, seed=9)
    params = mlp.init_mlp_params(jax.random.PRNGKey(4), 4, (8,), 1)
    scale = [1.0] * len(sizes); scale[1] = -5.0
    mesh = make_host_mesh(model=1)
    for agg in ROBUST_AGGREGATORS:
        kw = dict(opt=adamw(1e-2), rounds=rounds, local_epochs=epochs,
                  batch_size=16, aggregator=agg, seed=23, engine="scan",
                  dropout_rate=0.3, silo_scale=scale,
                  trim_frac=%r, krum_f=%r)
        base = run_federated(loss, params, silos, **kw)
        sh = run_federated(loss, params, silos, mesh=mesh, **kw)
        diff = max(float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
                   for a, b in zip(jax.tree_util.tree_leaves(base.params),
                                   jax.tree_util.tree_leaves(sh.params)))
        assert diff <= 1e-4, (agg, diff)
        print("SHARD_AGREE", agg, diff)
""") % (TRIM_FRAC, KRUM_F)


def check_sharded_agreement(sizes, rounds: int, epochs: int) -> Dict[str, float]:
    """8 virtual devices in a subprocess (the parent may already own a
    1-device jax): sharded == unsharded ≤1e-4 for every robust aggregator
    under dropout + a scaled silo."""
    repo = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run(
        [sys.executable, "-c", SHARDED_SCRIPT, json.dumps(list(sizes)),
         str(rounds), str(epochs)],
        capture_output=True, text=True, timeout=900, cwd=repo,
        env={**os.environ, "PYTHONPATH": "src", "JAX_PLATFORMS": "cpu"})
    assert r.returncode == 0, r.stderr[-3000:]
    out = {}
    for line in r.stdout.splitlines():
        if line.startswith("SHARD_AGREE"):
            _, agg, diff = line.split()
            out[agg] = float(diff)
            print(f"[sharded] {agg:<13s} sharded==unsharded diff "
                  f"{float(diff):.2e}")
    assert set(out) == {"median", "trimmed_mean", "krum"}, r.stdout
    return out


def main(argv: Optional[List[str]] = None) -> Dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true", help="CI smoke grid")
    ap.add_argument("--out-dir", default="results")
    ap.add_argument("--skip-sharded", action="store_true",
                    help="skip the 8-virtual-device subprocess check")
    args = ap.parse_args(argv)

    sizes = (16, 12, 20, 14, 18, 15) if args.fast else (40, 28, 52, 33, 45, 37)
    rounds, epochs = (6, 2) if args.fast else (12, 2)

    rows = run_grid(sizes, rounds, epochs)
    drop_rows = run_grid(sizes, rounds, epochs, dropout_rate=0.3)
    engines = check_engine_agreement(sizes, max(rounds // 2, 2), epochs)
    sharded = None if args.skip_sharded else check_sharded_agreement(
        sizes, max(rounds // 2, 2), epochs)

    def cell(rows, scenario, agg):
        return next(r for r in rows
                    if r["scenario"] == scenario and r["aggregator"] == agg)

    # §8 acceptance: under gradient scaling, the best robust aggregator
    # lands ≤ 0.5× fedavg — on the reported loss (the corrupted silo's
    # data is honest under grad_scale) AND on honest-data eval — and it
    # stays comparable to the clean-run reference, not merely "less bad".
    checks = {}
    ref = cell(rows, "clean", "fedavg")["honest_loss"]
    for scen in ("grad_scale_x1", "grad_scale_x2"):
        fed = cell(rows, scen, "fedavg")
        best = min((cell(rows, scen, a) for a in AGGREGATORS[1:]),
                   key=lambda r: r["honest_loss"])
        assert best["final_loss"] <= 0.5 * fed["final_loss"], \
            (scen, best, fed)
        assert best["honest_loss"] <= 0.5 * fed["honest_loss"], \
            (scen, best, fed)
        assert best["honest_loss"] <= 4.0 * ref + 0.1, (scen, best, ref)
        checks[scen] = {"fedavg": fed["final_loss"],
                        "best_robust": best["aggregator"],
                        "best_final_loss": best["final_loss"],
                        "ratio": round(best["final_loss"] /
                                       max(fed["final_loss"], 1e-12), 4)}
        print(f"[accept] {scen}: {best['aggregator']} "
              f"{best['final_loss']:.4f} vs fedavg {fed['final_loss']:.4f} "
              f"(x{checks[scen]['ratio']:.3f})")
    # label-flip: judged on honest data only (see run_grid docstring)
    fed = cell(rows, "label_flip_x1", "fedavg")
    best = min((cell(rows, "label_flip_x1", a) for a in AGGREGATORS[1:]),
               key=lambda r: r["honest_loss"])
    assert best["honest_loss"] < fed["honest_loss"], (best, fed)
    checks["label_flip_x1"] = {"fedavg_honest": fed["honest_loss"],
                               "best_robust": best["aggregator"],
                               "best_honest_loss": best["honest_loss"]}

    out = {
        "bench": "fed_robust_ablation",
        "sizes": list(sizes), "rounds": rounds, "local_epochs": epochs,
        "trim_frac": TRIM_FRAC, "krum_f": KRUM_F,
        "grid": rows, "dropout_grid": drop_rows,
        "engine_agreement_maxdiff": engines,
        "sharded_agreement_maxdiff": sharded,
        "acceptance": checks,
    }
    os.makedirs(args.out_dir, exist_ok=True)
    path = os.path.join(args.out_dir, "BENCH_fed_robust.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"[robust-ablation] -> {path}")
    return out


if __name__ == "__main__":
    main()
