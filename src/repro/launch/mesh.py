"""Production mesh construction.

make_production_mesh is a FUNCTION (not a module constant) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax device query.

Meshes (prescribed):
  single-pod : (16, 16)    axes ("data", "model")   = 256 chips (one v5e pod)
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

FedDCL mapping (DESIGN.md §5): in federated mode the silo axis is "pod" on
the multi-pod mesh (d = 2 DC-server groups, one per pod — cross-pod traffic
only at round boundaries, riding the scarce DCI exactly as the paper's
topology intends) and "data" on the single-pod mesh (d = 16 groups of one
16-chip model-parallel row each).
"""
from __future__ import annotations

from typing import Optional

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_host_mesh(model: int = 1, data: Optional[int] = None):
    """Small mesh over the actually-available devices (tests, examples)."""
    n = jax.device_count()
    data = data or (n // model)
    assert data * model <= n
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))


def silo_axis_name(mesh) -> str:
    return "pod" if "pod" in mesh.axis_names else "data"


def num_silos(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes[silo_axis_name(mesh)]
