"""Production mesh construction.

make_production_mesh is a FUNCTION (not a module constant) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax device query.

Meshes (prescribed):
  single-pod : (16, 16)    axes ("data", "model")   = 256 chips (one v5e pod)
  multi-pod  : (2, 16, 16) axes ("pod", "data", "model") = 512 chips

FedDCL mapping (DESIGN.md §5, §7): in federated mode the silo axis is "pod"
on the multi-pod mesh (d = 2 DC-server groups, one per pod — cross-pod
traffic only at round boundaries, riding the scarce DCI exactly as the
paper's topology intends) and "data" on the single-pod mesh (d = 16 groups
of one 16-chip model-parallel row each). The compiled tabular engine
(core.federated sharded plans) spans its silo dim over BOTH silo-capable
axes jointly — see `silo_axes`.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import numpy as np


def _axis_kwargs(n: int) -> dict:
    # jax >= 0.5 wants explicit AxisType; pinned 0.4.37 has neither the
    # enum nor the make_mesh kwarg — feature-detect instead of version-gate
    at = getattr(jax.sharding, "AxisType", None)
    return {"axis_types": (at.Auto,) * n} if at is not None else {}


def _make_mesh(shape, axes):
    try:
        return jax.make_mesh(shape, axes, **_axis_kwargs(len(axes)))
    except TypeError:                           # make_mesh without axis_types
        return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_host_mesh(model: int = 1, data: Optional[int] = None):
    """Small ("data", "model") mesh over the actually-available devices
    (tests, examples).

    `model` must divide the device count; `data` defaults to the LARGEST
    count such that data × model devices exist (n // model), so e.g. 6
    devices with model=2 give a 3×2 mesh over the first 6 devices. An
    explicit `data` whose product exceeds the device count raises
    immediately with the device count named — the old `data * model <= n`
    assert admitted shapes like data=1, model=4 on 6 devices, which only
    failed later and opaquely inside mesh consumers.
    """
    n = jax.device_count()
    if model < 1 or n // model < 1:
        raise ValueError(
            f"make_host_mesh: model={model} needs at least {model} devices, "
            f"but only {n} are available")
    if data is None:
        data = n // model
    if data < 1 or data * model > n:
        raise ValueError(
            f"make_host_mesh: requested {data}×{model} mesh needs "
            f"{data * model} devices, but only {n} are available "
            f"(largest valid data for model={model} is {n // model})")
    devices = np.asarray(jax.devices()[:data * model]).reshape(data, model)
    return jax.sharding.Mesh(devices, ("data", "model"))


def silo_axis_name(mesh) -> str:
    return "pod" if "pod" in mesh.axis_names else "data"


def silo_axes(mesh) -> Tuple[str, ...]:
    """The mesh axes the compiled engine shards the silo dim over —
    ("pod", "data") jointly when both exist (hierarchical aggregation:
    intra-pod psum first, cross-pod second), else the first axis."""
    from repro.core.federated import default_silo_axes
    return default_silo_axes(mesh)


def num_silos(mesh) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes[silo_axis_name(mesh)]
