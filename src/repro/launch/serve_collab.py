"""Multi-tenant collaboration serving driver (DESIGN.md §10).

Fits a small FedDCL model on synthetic tabular data, stands up a
`ServeCollab` server over it, and pushes a mixed stream of heterogeneous
requests (random tenants, random row counts) through the bucketed resident
step — then optionally onboards a new user onto the LIVE server and keeps
serving. Prints latency percentiles, per-bucket dispatch counts, and the
plan-cache hit/miss tally (warm steady state should show 0 further misses).

  PYTHONPATH=src python -m repro.launch.serve_collab --requests 64
  PYTHONPATH=src python -m repro.launch.serve_collab --onboard --backend device
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.api import FedDCL
from repro.data.partition import split_iid
from repro.data.tabular import make_dataset, train_test_split


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dataset", default="battery_small")
    ap.add_argument("--groups", type=int, default=3)
    ap.add_argument("--users", type=int, default=2, help="users per group")
    ap.add_argument("--n-ij", type=int, default=80, help="rows per user")
    ap.add_argument("--m-tilde", type=int, default=None,
                    help="default: the dataset's paper reduced dim")
    ap.add_argument("--rounds", type=int, default=5)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--max-rows", type=int, default=48,
                    help="max rows per request")
    ap.add_argument("--max-batch", type=int, default=64)
    ap.add_argument("--backend", default="host", choices=["host", "device"])
    ap.add_argument("--onboard", action="store_true",
                    help="onboard a new user onto the live server mid-run")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # ---- fit a small model ----------------------------------------------
    ds = make_dataset(args.dataset, n=4000, seed=args.seed)
    need = args.groups * args.users * args.n_ij
    (Xtr, Ytr), (Xte, _) = train_test_split(ds, need + args.n_ij, 512,
                                            seed=args.seed)
    Xs, Ys = split_iid(Xtr[:need], Ytr[:need], d=args.groups,
                       c=[args.users] * args.groups, n_ij=args.n_ij,
                       seed=args.seed)
    m_tilde = args.m_tilde or ds.cfg.reduced_dim
    model = FedDCL(m_tilde=m_tilde, rounds=args.rounds, task=ds.task,
                   svd_backend=args.backend, seed=args.seed)
    t0 = time.perf_counter()
    model.fit(Xs, Ys)
    print(f"fit: {args.groups} groups x {args.users} users "
          f"in {time.perf_counter() - t0:.2f}s")

    # ---- serve a mixed-tenant stream ------------------------------------
    srv = model.serve(max_batch=args.max_batch)
    rng = np.random.default_rng(args.seed + 1)
    m = Xs[0][0].shape[1]
    for _ in range(args.requests):
        g = int(rng.integers(0, args.groups))
        u = int(rng.integers(0, args.users))
        n = int(rng.integers(1, args.max_rows + 1))
        srv.submit(rng.standard_normal((n, m)), g, u)
    t0 = time.perf_counter()
    out = srv.serve()
    dt = time.perf_counter() - t0
    done = sum(1 for s in out.status.values() if s == "done")
    st = srv.stats()
    print(f"served {done}/{len(out)} requests, {st['rows_served']} rows "
          f"in {dt:.3f}s ({st['rows_served'] / dt:.0f} rows/s)")
    print(f"  p50 latency {st['p50_latency_s'] * 1e3:.2f}ms | "
          f"p99 {st['p99_latency_s'] * 1e3:.2f}ms")
    print(f"  buckets: {st['buckets']}")
    print(f"  plan cache: {st['cache']}")

    # ---- live onboarding -------------------------------------------------
    if args.onboard:
        Xn = Xtr[need:need + args.n_ij]
        Yn = Ytr[need:need + args.n_ij]
        t0 = time.perf_counter()
        j = srv.onboard_user(0, Xn, Yn)
        dt = time.perf_counter() - t0
        print(f"onboarded user {j} into group 0 in {dt * 1e3:.1f}ms "
              f"(incremental — no full protocol recompute)")
        for _ in range(8):
            srv.submit(rng.standard_normal(
                (int(rng.integers(1, args.max_rows + 1)), m)), 0, j)
        out2 = srv.serve()
        print(f"served {len(out2)} requests through the new tenant; "
              f"cache now: {srv.stats()['cache']}")


if __name__ == "__main__":
    main()
