"""Roofline-term extraction from a compiled dry-run artifact.

Hardware model (prescribed — TPU v5e-class):
    peak   197 TFLOP/s bf16 per chip
    HBM    819 GB/s per chip
    ICI    ~50 GB/s per link per chip

Terms (seconds, per step, per chip — cost_analysis() on the partitioned
module is PER-DEVICE, verified empirically in this container):
    compute    = flops_per_device / peak
    memory     = bytes_per_device / hbm_bw
    collective = collective_bytes_per_device / link_bw

collective bytes are parsed from the post-SPMD HLO: the sum of result-shape
bytes of every all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute (documented approximation: ring all-reduce moves ~2× its
buffer; we report raw buffer bytes and the per-kind breakdown so any factor
can be applied downstream).
"""
from __future__ import annotations

import math
import re
from typing import Any, Dict

HW = {
    "peak_flops": 197e12,       # bf16 FLOP/s per chip
    "hbm_bw": 819e9,            # B/s per chip
    "link_bw": 50e9,            # B/s per link
}

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f16": 2, "bf16": 2, "f32": 4, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s*(?P<shapes>\(?[^)=]*?\)?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shapes_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


_RG_RE = re.compile(
    r"replica_groups=(\{\{[\d, {}]*\}\}|\{\}|\[[\d,]+\]<=\[[\d,]+\](?:T\(([\d,]+)\))?)")


def parse_replica_groups(attr: str, num_devices: int = 0):
    """Decode an HLO replica_groups attribute into explicit device groups.
    Handles the explicit form {{0,1},{2,3}} and the iota form
    [G,S]<=[dims](T(perm)) used by newer XLA."""
    import numpy as np

    attr = attr.strip()
    if attr == "{}":
        return [list(range(num_devices))]
    if attr.startswith("{{"):
        return [[int(x) for x in g.replace("{", "").replace("}", "").split(",")
                 if x.strip()] for g in attr[2:-2].split("},{")]
    m = re.match(r"\[([\d,]+)\]<=\[([\d,]+)\](?:T\(([\d,]+)\))?", attr)
    if not m:
        return []
    gshape = [int(x) for x in m.group(1).split(",")]
    ishape = [int(x) for x in m.group(2).split(",")]
    arr = np.arange(int(np.prod(ishape))).reshape(ishape)
    if m.group(3):
        arr = arr.transpose([int(x) for x in m.group(3).split(",")])
    arr = arr.reshape(gshape)
    return arr.tolist()


def iter_collectives(hlo_text: str, num_devices: int = 0):
    """Yield (op_kind, result_bytes, groups) for every collective in the
    post-SPMD HLO ('-done' halves of async pairs skipped)."""
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        g = _RG_RE.search(line)
        groups = parse_replica_groups(g.group(1), num_devices) if g else []
        yield m.group("op"), _shape_bytes(m.group("shapes")), groups


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Per-device bytes by collective kind (result-shape bytes, `-done` ops
    skipped so async pairs aren't double-counted)."""
    out: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if "-done(" in line:
            continue
        m = _COLL_RE.search(line)
        if not m:
            continue
        b = _shape_bytes(m.group("shapes"))
        out[m.group("op")] = out.get(m.group("op"), 0) + b
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return out


def model_flops(cfg, shape, kind: str, local_steps: int = 1) -> float:
    """MODEL_FLOPS = 6·N·D (train) / 2·N·D (inference), N = active,
    non-embedding params; D = tokens processed by the lowered program."""
    from repro.models.backbone import count_params_analytic

    n = count_params_analytic(cfg, active_only=True, include_embed=False)
    if kind in ("train", "fed_local"):
        # fed_local processes the full global batch (d silos × local batch)
        toks = shape.global_batch * shape.seq_len
        return 6.0 * n * toks
    if kind == "fed_sync":
        return 0.0
    if kind == "prefill":
        toks = shape.global_batch * shape.seq_len
        return 2.0 * n * toks
    # decode: one token per sequence
    return 2.0 * n * shape.global_batch


def cross_block_bytes(hlo_text: str, block: int, num_devices: int) -> int:
    """Per-device bytes of collectives whose replica groups span more than
    one contiguous device block of `block` devices — i.e. traffic that must
    cross the silo/pod boundary (devices are laid out silo-major)."""
    total = 0
    for _op, nbytes, groups in iter_collectives(hlo_text, num_devices):
        for grp in groups:
            if len({d // block for d in grp}) > 1:
                total += nbytes
                break
    return total


def analyze(compiled, cfg, shape, kind: str, *, chips: int,
            local_steps: int = 1, silo_block: int = 0) -> Dict[str, Any]:
    cost = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    coll_dev = float(coll.get("total", 0))

    compute_s = flops_dev / HW["peak_flops"]
    memory_s = bytes_dev / HW["hbm_bw"]
    collective_s = coll_dev / HW["link_bw"]
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)

    mf = model_flops(cfg, shape, kind, local_steps)
    hlo_global = flops_dev * chips
    xs_bytes = (cross_block_bytes(hlo, silo_block, chips)
                if silo_block else None)
    return {
        **({"cross_silo_bytes_per_device": xs_bytes,
            "silo_block": silo_block} if xs_bytes is not None else {}),
        "arch": cfg.name,
        "shape": shape.name,
        "kind": kind,
        "chips": chips,
        "flops_per_device": flops_dev,
        "hlo_flops_global": hlo_global,
        "bytes_per_device": bytes_dev,
        "collective_bytes_per_device": coll_dev,
        "collective_breakdown": coll,
        **terms,
        "dominant": dominant,
        "model_flops": mf,
        "useful_flops_ratio": (mf / hlo_global) if hlo_global else 0.0,
        "roofline_bound_s": max(terms.values()),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        },
    }


def fmt_row(r: Dict[str, Any]) -> str:
    return (f"{r['arch']:>22s} {r['shape']:>11s} {r['kind']:>9s} "
            f"C={r['compute_s']*1e3:9.3f}ms M={r['memory_s']*1e3:9.3f}ms "
            f"X={r['collective_s']*1e3:9.3f}ms dom={r['dominant'][:-2]:>10s} "
            f"useful={r['useful_flops_ratio']*100:5.1f}% "
            f"mem/dev={(r['memory']['argument_bytes']+r['memory']['temp_bytes'])/2**30:6.2f}GiB")
