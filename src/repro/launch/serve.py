"""Batched serving driver: continuous-batching-lite over the cached decode
path (prefill + per-token decode with slot reuse).

A RequestQueue of prompts is served by a fixed-width slot table: finished
sequences release their slot to the next queued request mid-flight; the
decode step always runs the full (padded) batch, which is exactly how the
production decode shapes (decode_32k / long_500k) are lowered.
"""
from __future__ import annotations

import argparse
import time
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, REDUCED
from repro.models import backbone as bb
from repro.models.modality import synthetic_prefix


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # (P,) int32
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class ServeResult(Dict[int, List[int]]):
    """{rid: tokens} plus `.status`: {rid: done|truncated|pending}.

    `serve()` stops at `max_steps` whether or not every request finished;
    without per-request status a half-decoded request was indistinguishable
    from a finished one. "done" reached `max_new`, "truncated" was admitted
    and emitted tokens but got cut off, "pending" never reached a slot.
    """

    def __init__(self, outputs: Dict[int, List[int]],
                 status: Dict[int, str]):
        super().__init__(outputs)
        self.status = status


class BatchedServer:
    """Slot-table continuous batching over decode_step."""

    def __init__(self, cfg, params, *, slots: int = 4, cache_len: int = 512,
                 temperature: float = 0.0, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = slots
        self.cache_len = cache_len
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.state = bb.init_decode_state(cfg, slots, cache_len, jnp.float32)
        self.pos = jnp.zeros((slots,), jnp.int32)
        self.cur_tok = jnp.zeros((slots, 1), jnp.int32)
        self.active: List[Optional[Request]] = [None] * slots
        self._decode = jax.jit(
            lambda p, s, t, c: bb.decode_step(p, s, t, c, cfg,
                                              compute_dtype=jnp.float32))

    def _prefill_slot(self, slot: int, req: Request):
        # per-slot prefill on a B=1 slice of the slot's cache (every decode
        # state leaf carries batch at axis 1): the prompt decodes as P
        # single-sequence steps instead of P full-batch steps, and live
        # slots' state is untouched by construction — admission cost no
        # longer scales with the slot count. Batched prefill stays the
        # prefill_32k path.
        toks = req.prompt
        self.pos = self.pos.at[slot].set(len(toks))
        if len(toks) == 0:
            # empty prompt: nothing to prefill (and no logits to sample
            # from) — seed the slot with token 0 at pos 0 and let the next
            # batched decode step produce the first output token
            self.cur_tok = self.cur_tok.at[slot, 0].set(0)
            return
        sub = jax.tree.map(lambda a: a[:, slot:slot + 1], self.state)
        tok = jnp.zeros((1, 1), jnp.int32)
        pos = jnp.zeros((1,), jnp.int32)
        for i, t in enumerate(toks):
            tok = tok.at[0, 0].set(int(t))
            pos = pos.at[0].set(i)
            logits, sub = self._decode(self.params, sub, tok, pos)
        self.state = jax.tree.map(
            lambda full, s: full.at[:, slot:slot + 1].set(s),
            self.state, sub)
        nxt = self._sample(logits[0, 0], req)
        req.out.append(int(nxt))
        self.cur_tok = self.cur_tok.at[slot, 0].set(int(nxt))

    def _sample(self, logits: jnp.ndarray, req: Request) -> int:
        if self.temperature <= 0:
            return int(jnp.argmax(logits))
        # per-request stream: the key depends only on (rid, #tokens emitted
        # so far), never on which slot the request landed in or what its
        # batch-mates were doing — temperature>0 output is reproducible
        # across admission orders and slot layouts (a split-per-sample
        # self.key made every sample depend on global serve history)
        k = jax.random.fold_in(jax.random.fold_in(self.key, req.rid),
                               len(req.out))
        return int(jax.random.categorical(k, logits / self.temperature))

    def serve(self, requests: List[Request], *, max_steps: int = 10_000
              ) -> ServeResult:
        queue = deque(requests)        # FIFO: O(1) popleft, not list.pop(0)
        steps = 0
        while (any(self.active) or queue) and steps < max_steps:
            # admit
            for s in range(self.slots):
                if self.active[s] is None and queue:
                    req = queue.popleft()
                    self.active[s] = req
                    self._prefill_slot(s, req)
            if not any(self.active):
                break
            # one batched decode step; only LIVE slots advance their
            # position — an always-advancing pos silently marched idle
            # slots past cache_len (clamped/dropped cache writes under
            # jit) and kept released slots decoding stale tokens
            live = jnp.asarray([0 if r is None else 1 for r in self.active],
                               jnp.int32)
            logits, self.state = self._decode(self.params, self.state,
                                              self.cur_tok, self.pos)
            self.pos = self.pos + live
            steps += 1
            new_toks = self.cur_tok
            for s, req in enumerate(self.active):
                if req is None:
                    continue
                nxt = self._sample(logits[s, 0], req)
                req.out.append(nxt)
                new_toks = new_toks.at[s, 0].set(nxt)
                if len(req.out) >= req.max_new:
                    req.done = True
                    self.active[s] = None      # release slot mid-flight...
                    self.pos = self.pos.at[s].set(0)       # ...and reset it
                    new_toks = new_toks.at[s, 0].set(0)
            self.cur_tok = new_toks
        status = {r.rid: ("done" if r.done
                          else "truncated" if r.out else "pending")
                  for r in requests}
        return ServeResult({r.rid: r.out for r in requests}, status)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = REDUCED[args.arch]
    key = jax.random.PRNGKey(args.seed)
    params = bb.init_params(cfg, key, jnp.float32)
    rng = np.random.default_rng(args.seed)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=rng.integers(4, 12)),
                    max_new=args.max_new)
            for i in range(args.requests)]
    server = BatchedServer(cfg, params, slots=args.slots, cache_len=256)
    t0 = time.perf_counter()
    outs = server.serve(reqs)
    dt = time.perf_counter() - t0
    total = sum(len(v) for v in outs.values())
    print(f"served {len(reqs)} requests, {total} tokens in {dt:.2f}s "
          f"({total/dt:.1f} tok/s, slots={args.slots})")
    for rid, toks in sorted(outs.items()):
        print(f"  req {rid}: {len(toks)} tokens -> {toks[:8]}...")


if __name__ == "__main__":
    main()
