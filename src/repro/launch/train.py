"""Training driver: baseline data-parallel OR FedDCL federated (silo-local
steps + periodic cross-silo FedAvg), on whatever devices exist.

On this CPU container it trains real (reduced) models on the synthetic token
pipeline; on a TPU pod the same code runs the production mesh — only
--mesh differs. Used by examples/feddcl_llm_pretrain.py and the end-to-end
driver run recorded in EXPERIMENTS.md.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3.2-1b --reduced \
      --steps 200 --batch 8 --seq 256 --silos 4 --local-steps 4
"""
from __future__ import annotations

import argparse
import json
import os
import time
from dataclasses import replace

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import store
from repro.configs import ARCHS, REDUCED
from repro.configs.base import FederatedConfig, InputShape, TrainConfig
from repro.core.federated import silo_replicate
from repro.data.tokens import TokenStream, silo_batches
from repro.launch import steps as steps_lib
from repro.models import backbone as bb
from repro.models.modality import synthetic_prefix


def train(arch: str, *, reduced: bool = True, steps: int = 100, batch: int = 8,
          seq: int = 256, silos: int = 1, local_steps: int = 4,
          rounds_per_dispatch: int = 1,
          lr: float = 3e-4, seed: int = 0, non_iid: bool = False,
          log_every: int = 10, checkpoint_path: str | None = None,
          log_path: str | None = None, param_dtype: str = "float32",
          compute_dtype: str = "float32"):
    cfg = (REDUCED if reduced else ARCHS)[arch]
    shape = InputShape("cli", seq_len=seq, global_batch=batch, kind="train")
    tc = TrainConfig(
        model=cfg, shape=shape, learning_rate=lr, warmup_steps=max(steps // 20, 5),
        total_steps=steps, param_dtype=param_dtype, compute_dtype=compute_dtype,
        federated=FederatedConfig(num_silos=silos, local_steps=local_steps),
        remat=False, seed=seed)

    key = jax.random.PRNGKey(seed)
    params = bb.init_params(cfg, key, jnp.dtype(param_dtype))
    n_params = bb.count_params_analytic(cfg)
    print(f"arch={cfg.name} params={n_params/1e6:.2f}M silos={silos} "
          f"H={local_steps} batch={batch}x{seq}")

    history = []
    federated = silos > 1
    prefix = (lambda k, b: synthetic_prefix(k, cfg, b)) if cfg.prefix_frontend else None

    if federated:
        # One FedDCL round (H vmapped silo-local steps + the fedavg_sync
        # boundary) is ONE compiled dispatch — the launch-tier consumption
        # of the core.federated scan engine (DESIGN.md §4).
        round_step, opt = steps_lib.make_federated_round_step(cfg, tc)
        round_step = jax.jit(round_step, donate_argnums=(0, 1))
        assert batch % silos == 0
        sp = silo_replicate(params, silos)
        so = jax.vmap(opt.init)(sp)
        t0 = time.perf_counter()

        def stacked_batches(step0, h):
            """Stack h consecutive per-silo batches with leading dim h."""
            nbs = [silo_batches(cfg.vocab_size, seq, batch // silos, silos,
                                step0 + i, seed=seed, non_iid=non_iid)
                   for i in range(h)]
            b = {k: jnp.asarray(np.stack([nb[k] for nb in nbs]))
                 for k in nbs[0]}
            if prefix is not None:
                def step_prefix(k):
                    return jax.vmap(lambda kk: prefix(kk, batch // silos))(
                        jax.random.split(k, silos))
                pks = jnp.stack([jax.random.fold_in(key, step0 + i)
                                 for i in range(h)])
                b["prefix_embeds"] = jax.vmap(step_prefix)(pks)
            return b

        def log_round(step0, metrics):
            h = int(metrics["loss"].shape[0])
            for i in range(h):
                step = step0 + i
                if step % log_every == 0 or step == steps - 1:
                    rec = {"step": step,
                           "loss": float(jnp.mean(metrics["loss"][i])),
                           "elapsed_s": time.perf_counter() - t0}
                    history.append(rec)
                    print(f"step {step:5d} loss {rec['loss']:.4f} "
                          f"({rec['elapsed_s']:.1f}s)")

        rpd = max(rounds_per_dispatch, 1)
        if rpd > 1:
            # R rounds per dispatch: one lax.scan over round steps, metrics
            # silo-meaned to (R, H) scalars inside the scan (bounded memory)
            multi_step, _ = steps_lib.make_federated_multiround_step(cfg, tc)
            multi_step = jax.jit(multi_step, donate_argnums=(0, 1))

            def multiround_batches(step0, r, h):
                bs = [stacked_batches(step0 + i * h, h) for i in range(r)]
                return {k: jnp.stack([b[k] for b in bs]) for k in bs[0]}

        n_rounds = steps // local_steps
        rnd = 0
        while rnd < n_rounds:
            step0 = rnd * local_steps
            if rpd > 1 and n_rounds - rnd >= rpd:
                sp, so, metrics = multi_step(
                    sp, so, multiround_batches(step0, rpd, local_steps))
                for r in range(rpd):
                    log_round(step0 + r * local_steps,
                              jax.tree.map(lambda a, r=r: a[r], metrics))
                rnd += rpd
            else:
                sp, so, metrics = round_step(
                    sp, so, stacked_batches(step0, local_steps))
                log_round(step0, metrics)
                rnd += 1
        rem = steps % local_steps
        if rem:
            # trailing steps of an unfinished round: local steps, no sync —
            # same semantics as the old per-step loop
            phase, _ = steps_lib.make_federated_local_phase_step(cfg, tc)
            phase = jax.jit(phase, donate_argnums=(0, 1))
            sp, so, metrics = phase(sp, so, stacked_batches(steps - rem, rem))
            log_round(steps - rem, metrics)
        params = jax.tree.map(lambda a: a[0], sp)
    else:
        step_fn, opt = steps_lib.make_train_step(cfg, tc)
        step_fn = jax.jit(step_fn, donate_argnums=(0, 1))
        opt_state = opt.init(params)
        stream = TokenStream(cfg.vocab_size, seq, batch, seed=seed)
        t0 = time.perf_counter()
        for step in range(steps):
            nb = stream.batch(step)
            b = {k: jnp.asarray(v) for k, v in nb.items()}
            if prefix is not None:
                b["prefix_embeds"] = prefix(jax.random.fold_in(key, step), batch)
            params, opt_state, metrics = step_fn(params, opt_state, b)
            if step % log_every == 0 or step == steps - 1:
                rec = {"step": step, "loss": float(metrics["loss"]),
                       "elapsed_s": time.perf_counter() - t0}
                history.append(rec)
                print(f"step {step:5d} loss {rec['loss']:.4f} "
                      f"({rec['elapsed_s']:.1f}s)")

    if checkpoint_path:
        store.save(checkpoint_path, params,
                   {"arch": cfg.name, "steps": steps, "reduced": reduced})
        print(f"checkpoint -> {checkpoint_path}")
    if log_path:
        os.makedirs(os.path.dirname(os.path.abspath(log_path)), exist_ok=True)
        with open(log_path, "w") as f:
            json.dump({"arch": cfg.name, "silos": silos, "H": local_steps,
                       "history": history}, f, indent=1)
    return params, history


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=sorted(ARCHS))
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--silos", type=int, default=1)
    ap.add_argument("--local-steps", type=int, default=4)
    ap.add_argument("--rounds-per-dispatch", type=int, default=1,
                    help="FedDCL rounds fused into one compiled dispatch "
                         "(lax.scan over round steps); 1 = one dispatch per "
                         "round (unchanged default)")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--non-iid", action="store_true")
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--log", default=None)
    args = ap.parse_args()
    train(args.arch, reduced=args.reduced, steps=args.steps, batch=args.batch,
          seq=args.seq, silos=args.silos, local_steps=args.local_steps,
          rounds_per_dispatch=args.rounds_per_dispatch,
          lr=args.lr, seed=args.seed, non_iid=args.non_iid,
          checkpoint_path=args.checkpoint, log_path=args.log)


if __name__ == "__main__":
    main()
