import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, print memory/cost analysis, dump roofline terms.

The two lines above MUST stay the first statements in this file — jax locks
the device count at first backend initialization, and the 512 placeholder
host devices exist ONLY for the dry-run (smoke tests and benchmarks see the
real single device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--mode feddcl]
  PYTHONPATH=src python -m repro.launch.dryrun --all --both-meshes --out results/dryrun

Exit code is non-zero if any requested pair fails to lower+compile — the
dry-run IS the test of distribution-config coherence.
"""
import argparse
import json
import sys
import time
import traceback

import jax

from repro.configs import ARCHS, INPUT_SHAPES
from repro.configs.base import FederatedConfig, TrainConfig
from repro.launch import roofline
from repro.launch.mesh import make_production_mesh, num_silos
from repro.launch.specs import make_plan, resolve_arch_for_shape


def run_pair(arch: str, shape_name: str, *, multi_pod: bool, mode: str,
             out_dir: str | None, verbose: bool = True,
             scan_only: bool = False, moe_impl: str | None = None,
             tag: str = "", variant: str | None = None) -> dict:
    import dataclasses as _dc

    cfg = ARCHS[arch]
    if variant == "rwkv_seq":        # §Perf: sequence-parallel WKV chunks
        cfg = cfg.with_overrides(ssm=_dc.replace(cfg.ssm, shard="seq"))
    elif variant == "expand_kv":     # §Perf: head-parallel decode, replicated cache
        cfg = cfg.with_overrides(decode_expand_kv=True)
    elif variant == "cache_seq":     # §Perf: sequence-sharded decode cache
        cfg = cfg.with_overrides(decode_cache_seq=True)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size

    # deepseek-v3 cannot hold fp32 AdamW moments at 256 chips — bf16 moments
    # (DESIGN.md §5; the memory_analysis printout is the receipt).
    opt_dtype = "bfloat16" if arch == "deepseek-v3-671b" else "float32"
    tc = TrainConfig(model=cfg, shape=shape, param_dtype="bfloat16",
                     compute_dtype="bfloat16", opt_state_dtype=opt_dtype,
                     federated=FederatedConfig(num_silos=num_silos(mesh),
                                               local_steps=4))
    from repro.models.layers import unrolled

    # Two compiles per pair (measured in this container, see EXPERIMENTS.md
    # §Dry-run methodology):
    #  * scan-over-layers -> memory_analysis peak is liveness-accurate
    #    (while-loop buffers are reused per iteration);
    #  * statically unrolled -> cost_analysis FLOPs/bytes and the HLO
    #    collective set are trip-count-honest (XLA counts loop bodies ONCE),
    #    but the CPU backend's scheduler inflates unrolled temp memory.
    t0 = time.perf_counter()
    plan = make_plan(cfg, shape, mesh, mode=mode, tc=tc, moe_impl=moe_impl)

    def compile_plan(unroll: bool):
        import contextlib
        ctx = unrolled() if unroll else contextlib.nullcontext()
        # fresh closure per compile: the unroll flag is a trace-time global,
        # so the two builds must not share a jit cache entry
        fn = plan.step_fn
        wrapped = lambda *a: fn(*a)  # noqa: E731
        with mesh, ctx:
            jitted = jax.jit(wrapped,
                             in_shardings=plan.in_shardings,
                             out_shardings=plan.out_shardings,
                             donate_argnums=plan.donate_argnums)
            return jitted.lower(*plan.args).compile()

    # scan_only: one compile (memory + compile-success proof); cost numbers
    # then carry the while-loop undercount and are flagged in the record.
    compiled_scan = compile_plan(unroll=False)  # memory source
    compiled = compiled_scan if scan_only else compile_plan(unroll=True)
    t1 = time.perf_counter()

    # silo boundary: contiguous pod block (multi-pod) or data row (single-pod)
    silo_block = 256 if multi_pod else 16
    rec = roofline.analyze(
        compiled, resolve_arch_for_shape(cfg, shape), shape, plan.kind,
        chips=chips, silo_block=silo_block,
        local_steps=tc.federated.local_steps if plan.kind == "fed_local" else 1)
    ma_scan = compiled_scan.memory_analysis()
    rec["memory"] = {
        "argument_bytes": ma_scan.argument_size_in_bytes,
        "output_bytes": ma_scan.output_size_in_bytes,
        "temp_bytes": ma_scan.temp_size_in_bytes,
        "alias_bytes": ma_scan.alias_size_in_bytes,
    }
    compiled = compiled_scan   # memory printout below reports the scan build
    rec.update({
        "mesh": "2x16x16" if multi_pod else "16x16",
        "mode": mode,
        "plan": plan.name,
        "compile_s": t1 - t0,
        "cost_source": "scan(undercounts loops)" if scan_only else "unrolled",
    })
    if verbose:
        ma = compiled.memory_analysis()
        print(f"== {plan.name} mesh={rec['mesh']} chips={chips} "
              f"compile={rec['compile_s']:.1f}s")
        print(f"   memory_analysis: args={ma.argument_size_in_bytes/2**30:.2f}GiB "
              f"out={ma.output_size_in_bytes/2**30:.2f}GiB "
              f"temp={ma.temp_size_in_bytes/2**30:.2f}GiB "
              f"alias={ma.alias_size_in_bytes/2**30:.2f}GiB  (per device)")
        print(f"   cost_analysis: flops/dev={rec['flops_per_device']:.3e} "
              f"bytes/dev={rec['bytes_per_device']:.3e} "
              f"coll_bytes/dev={rec['collective_bytes_per_device']:.3e}")
        print("   " + roofline.fmt_row(rec))
        sys.stdout.flush()
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"__{tag}" if tag else ""
        fn = f"{arch}__{shape_name}__{rec['mesh']}__{mode}{suffix}.json"
        with open(os.path.join(out_dir, fn), "w") as f:
            json.dump(rec, f, indent=1)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=sorted(ARCHS), action="append")
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES), action="append")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--mode", default="baseline",
                    choices=["baseline", "feddcl", "feddcl_sync"])
    ap.add_argument("--out", default=None)
    ap.add_argument("--scan-only", action="store_true",
                    help="single compile per pair (compile-proof + memory; "
                         "cost numbers carry the while-loop undercount)")
    ap.add_argument("--moe-impl", default=None, choices=["gspmd", "ep", "dense"])
    ap.add_argument("--variant", default=None,
                    choices=["rwkv_seq", "expand_kv", "cache_seq"])
    ap.add_argument("--tag", default="", help="suffix for output JSON names")
    args = ap.parse_args()

    archs = sorted(ARCHS) if (args.all or not args.arch) else args.arch
    shapes = sorted(INPUT_SHAPES) if (args.all or not args.shape) else args.shape
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    failures = []
    for arch in archs:
        for shape in shapes:
            if args.mode == "feddcl" and INPUT_SHAPES[shape].kind != "train":
                continue
            for mp in meshes:
                try:
                    run_pair(arch, shape, multi_pod=mp, mode=args.mode,
                             out_dir=args.out, scan_only=args.scan_only,
                             moe_impl=args.moe_impl, tag=args.tag,
                             variant=args.variant)
                except Exception as e:  # noqa: BLE001 — report and continue
                    failures.append((arch, shape, mp, repr(e)))
                    print(f"!! FAIL {arch} {shape} multi_pod={mp}: {e}")
                    traceback.print_exc()
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for f in failures:
            print("  ", f)
        return 1
    print("\nall requested dry-runs compiled OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
