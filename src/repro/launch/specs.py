"""input_specs(): ShapeDtypeStruct stand-ins + shardings for every
(architecture × input shape × mesh × mode) — the dry-run's contract.

No device allocation happens here: parameter/optimizer/cache shapes come
from jax.eval_shape over the real constructors, so the dry-run lowers the
EXACT production program.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import (FederatedConfig, InputShape, ModelConfig,
                                TrainConfig)
from repro.core.federated import silo_replicate
from repro.launch import steps as steps_lib
from repro.launch.mesh import num_silos, silo_axis_name
from repro.models import backbone as bb
from repro.shardingx.policy import batch_spec, param_specs, to_shardings

# decode keeps params tensor-parallel-only unless they cannot fit one model
# shard (deepseek-v3: 671B bf16 / 16 shards = 84 GB ≫ HBM -> FSDP too).
DECODE_FSDP_BYTES = 12e9


@dataclass
class LoweringPlan:
    name: str
    kind: str                       # train | fed_round | prefill | decode
    cfg: ModelConfig
    step_fn: Callable
    args: Tuple[Any, ...]           # ShapeDtypeStruct trees
    in_shardings: Tuple[Any, ...]
    out_shardings: Any
    donate_argnums: Tuple[int, ...] = ()


def resolve_arch_for_shape(cfg: ModelConfig, shape: InputShape) -> ModelConfig:
    """Long-context policy (DESIGN.md §8): at 500k decode every attention
    path runs sliding-window (ring cache); SSM/hybrid state paths unchanged."""
    if shape.name == "long_500k" and cfg.family != "ssm":
        return cfg.with_overrides(attn_variant="sliding", sliding_window=8192)
    return cfg


def decode_cache_len(cfg: ModelConfig, shape: InputShape) -> int:
    if cfg.attn_variant == "sliding":
        return min(shape.seq_len, cfg.sliding_window)
    return shape.seq_len + (cfg.prefix_len if cfg.prefix_frontend else 0)


# --------------------------------------------------------------------------
# cache sharding
# --------------------------------------------------------------------------

def cache_specs(state_shapes: Any, mesh: Mesh,
                replicate_model: bool = False,
                model_on_seq: bool = False) -> Any:
    """Decode-state PartitionSpecs. Arrays are (L, B, ...):
      batch dim over ("pod","data") when divisible; for B == 1 (long-context)
      the ring/cache length dim (index 2) is sequence-sharded instead;
      the model axis lands on the innermost divisible dim of index >= 3."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    bt = 1
    for a in batch_axes:
        bt *= sizes[a]
    msize = sizes.get("model", 1)

    def one(path, leaf):
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        if len(shape) < 2:
            return P(*spec)
        model_at = None
        if model_on_seq and len(shape) >= 3 and msize > 1 \
                and shape[2] % msize == 0 and shape[2] >= msize:
            spec[2] = "model"                 # cache length dim
            model_at = 2
        elif not replicate_model:
            for i in range(len(shape) - 1, 2, -1):
                if msize > 1 and shape[i] % msize == 0:
                    spec[i] = "model"
                    model_at = i
                    break
        if batch_axes:
            if shape[1] % bt == 0 and shape[1] > 1:
                spec[1] = batch_axes
            elif len(shape) >= 3 and 2 != model_at and shape[2] % bt == 0:
                spec[2] = batch_axes
        return P(*spec)

    return jax.tree_util.tree_map_with_path(one, state_shapes)


# --------------------------------------------------------------------------
# plan builders
# --------------------------------------------------------------------------

def _params_shapes(cfg: ModelConfig, dtype) -> Any:
    return jax.eval_shape(
        lambda: bb.init_params(cfg, jax.random.PRNGKey(0), dtype))


def _opt_specs(pspecs: Any) -> Any:
    return {"step": P(), "m": pspecs, "v": pspecs}


def make_plan(cfg_raw: ModelConfig, shape: InputShape, mesh: Mesh, *,
              mode: str = "baseline", tc: Optional[TrainConfig] = None,
              use_pallas: bool = False, moe_impl: Optional[str] = None) -> LoweringPlan:
    """mode: baseline | feddcl | feddcl_sync (train shapes) — decode/prefill
    ignore mode. moe_impl overrides the MoE dispatch (hillclimb: "ep")."""
    cfg = resolve_arch_for_shape(cfg_raw, shape)
    if moe_impl and cfg.moe is not None:
        cfg = cfg.with_overrides(moe=dataclasses.replace(cfg.moe, impl=moe_impl))
    if mode in ("feddcl", "feddcl_sync") and cfg.moe is not None \
            and cfg.moe.impl == "ep":
        # shard_map does not nest under the silo vmap — fed plans use gspmd
        cfg = cfg.with_overrides(moe=dataclasses.replace(cfg.moe, impl="gspmd"))
    tc = tc or TrainConfig(model=cfg, shape=shape)
    pdtype = jnp.dtype(tc.param_dtype)
    cdtype = jnp.dtype(tc.compute_dtype)

    if shape.kind == "train":
        if mode == "feddcl":
            return _fed_local_plan(cfg, shape, mesh, tc, use_pallas)
        if mode == "feddcl_sync":
            return _fed_sync_plan(cfg, shape, mesh, tc)
        return _train_plan(cfg, shape, mesh, tc, use_pallas)
    if shape.kind == "prefill":
        return _prefill_plan(cfg, shape, mesh, tc, use_pallas)
    return _decode_plan(cfg, shape, mesh, tc)


def _batch_shapes(cfg: ModelConfig, batch: int, seq: int, cdtype) -> Dict[str, Any]:
    d = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
    }
    if cfg.prefix_frontend:
        d["prefix_embeds"] = jax.ShapeDtypeStruct(
            (batch, cfg.prefix_len, cfg.d_model), cdtype)
    return d


def _train_plan(cfg, shape, mesh, tc, use_pallas) -> LoweringPlan:
    pdtype = jnp.dtype(tc.param_dtype)
    cdtype = jnp.dtype(tc.compute_dtype)
    step, opt = steps_lib.make_train_step(cfg, tc, use_pallas=use_pallas)
    pshapes = _params_shapes(cfg, pdtype)
    oshapes = jax.eval_shape(opt.init, pshapes)
    bshapes = _batch_shapes(cfg, shape.global_batch, shape.seq_len, cdtype)

    # NOTE: EP expert weights stay FSDP-sharded (policy default); moe_ep.py
    # declares matching in_specs and all-gathers them inside the shard_map.
    pspecs = param_specs(pshapes, mesh, fsdp=tc.fsdp)
    ospecs = _opt_specs(pspecs)
    bspec = batch_spec(mesh, federated=False)
    bspecs = {k: (bspec if v.ndim == 2 else
                  P(*(tuple(bspec)[:1] + (None,) * (v.ndim - 1))))
              for k, v in bshapes.items()}
    mspecs = jax.tree.map(lambda _: P(), {"loss": 0., "ce": 0., "grad_norm": 0.,
                                          **({"moe_aux": 0.} if cfg.moe else {}),
                                          **({"mtp": 0.} if cfg.mtp_depth else {})})
    return LoweringPlan(
        name=f"{cfg.name}:{shape.name}:train",
        kind="train", cfg=cfg, step_fn=step,
        args=(pshapes, oshapes, bshapes),
        in_shardings=tuple(to_shardings(s, mesh) for s in (pspecs, ospecs, bspecs)),
        out_shardings=tuple(to_shardings(s, mesh) for s in (pspecs, ospecs, mspecs)),
        donate_argnums=(0, 1),
    )


def _fed_common(cfg, shape, mesh, tc):
    d = num_silos(mesh)
    silo_ax = silo_axis_name(mesh)
    pdtype = jnp.dtype(tc.param_dtype)
    pshapes = _params_shapes(cfg, pdtype)
    sp_shapes = jax.eval_shape(lambda p: silo_replicate(p, d), pshapes)
    pspecs = param_specs(sp_shapes, mesh, fsdp=tc.fsdp, silo_dim=True,
                         silo_axis=silo_ax)
    return d, silo_ax, sp_shapes, pspecs


def _fed_local_plan(cfg, shape, mesh, tc, use_pallas) -> LoweringPlan:
    """FedDCL local step (d silos × local batch, zero cross-silo traffic)."""
    cdtype = jnp.dtype(tc.compute_dtype)
    d, silo_ax, sp_shapes, pspecs = _fed_common(cfg, shape, mesh, tc)
    assert shape.global_batch % d == 0, (shape.global_batch, d)
    local_b = shape.global_batch // d

    vstep, opt = steps_lib.make_federated_local_step(cfg, tc,
                                                     use_pallas=use_pallas)
    so_shapes = jax.eval_shape(lambda p: jax.vmap(opt.init)(p), sp_shapes)
    b1 = _batch_shapes(cfg, local_b, shape.seq_len, cdtype)
    bshapes = {k: jax.ShapeDtypeStruct((d,) + v.shape, v.dtype)
               for k, v in b1.items()}

    ospecs = {"step": P(silo_ax), "m": pspecs, "v": pspecs}
    inner_data = "data" if (silo_ax != "data" and "data" in mesh.axis_names) else None
    bspecs = {k: P(silo_ax, inner_data, *([None] * (v.ndim - 2)))
              for k, v in bshapes.items()}
    mspecs = jax.tree.map(lambda _: P(silo_ax),
                          {"loss": 0., "ce": 0., "grad_norm": 0.,
                           **({"moe_aux": 0.} if cfg.moe else {}),
                           **({"mtp": 0.} if cfg.mtp_depth else {})})
    return LoweringPlan(
        name=f"{cfg.name}:{shape.name}:feddcl-local(d={d})",
        kind="fed_local", cfg=cfg, step_fn=vstep,
        args=(sp_shapes, so_shapes, bshapes),
        in_shardings=tuple(to_shardings(s, mesh) for s in (pspecs, ospecs, bspecs)),
        out_shardings=tuple(to_shardings(s, mesh) for s in (pspecs, ospecs, mspecs)),
        donate_argnums=(0, 1),
    )


def _fed_sync_plan(cfg, shape, mesh, tc) -> LoweringPlan:
    """FedDCL round boundary: the single cross-silo all-reduce."""
    d, silo_ax, sp_shapes, pspecs = _fed_common(cfg, shape, mesh, tc)
    sync = steps_lib.make_fedavg_sync_step(tc)
    _, opt = steps_lib.make_federated_local_step(cfg, tc)
    so_shapes = jax.eval_shape(lambda p: jax.vmap(opt.init)(p), sp_shapes)
    ospecs = {"step": P(silo_ax), "m": pspecs, "v": pspecs}
    return LoweringPlan(
        name=f"{cfg.name}:{shape.name}:feddcl-sync(d={d},H={tc.federated.local_steps})",
        kind="fed_sync", cfg=cfg, step_fn=sync,
        args=(sp_shapes, so_shapes),
        in_shardings=tuple(to_shardings(s, mesh) for s in (pspecs, ospecs)),
        out_shardings=tuple(to_shardings(s, mesh) for s in (pspecs, ospecs)),
        donate_argnums=(0, 1),
    )


def _decode_params_fsdp(cfg: ModelConfig) -> bool:
    return bb.count_params_analytic(cfg) * 2 / 16 > DECODE_FSDP_BYTES


def _prefill_plan(cfg, shape, mesh, tc, use_pallas) -> LoweringPlan:
    cdtype = jnp.dtype(tc.compute_dtype)
    cache_len = decode_cache_len(cfg, shape)
    step = steps_lib.make_prefill_step(cfg, cache_len=cache_len,
                                       compute_dtype=cdtype,
                                       use_pallas=use_pallas)
    pshapes = _params_shapes(cfg, jnp.bfloat16)
    bshapes = _batch_shapes(cfg, shape.global_batch, shape.seq_len, cdtype)
    bshapes.pop("labels")

    pspecs = param_specs(pshapes, mesh, fsdp=_decode_params_fsdp(cfg))
    bspec = batch_spec(mesh, federated=False)
    bspecs = {k: (bspec if v.ndim == 2 else
                  P(*(tuple(bspec)[:1] + (None,) * (v.ndim - 1))))
              for k, v in bshapes.items()}
    out_shapes = jax.eval_shape(step, pshapes, bshapes)
    state_specs = cache_specs(out_shapes[1], mesh)
    out_specs = (P(), state_specs, P())
    return LoweringPlan(
        name=f"{cfg.name}:{shape.name}:prefill",
        kind="prefill", cfg=cfg, step_fn=step,
        args=(pshapes, bshapes),
        in_shardings=tuple(to_shardings(s, mesh) for s in (pspecs, bspecs)),
        out_shardings=to_shardings(out_specs, mesh),
    )


def _decode_plan(cfg, shape, mesh, tc) -> LoweringPlan:
    cdtype = jnp.dtype(tc.compute_dtype)
    cache_len = decode_cache_len(cfg, shape)
    B = shape.global_batch
    step = steps_lib.make_serve_step(cfg, compute_dtype=cdtype)
    pshapes = _params_shapes(cfg, jnp.bfloat16)
    sshapes = jax.eval_shape(
        lambda: bb.init_decode_state(cfg, B, cache_len, jnp.bfloat16))
    tshape = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    posshape = jax.ShapeDtypeStruct((B,), jnp.int32)

    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    bt = 1
    for a in batch_axes:
        bt *= sizes[a]
    tok_spec = P(batch_axes if B % bt == 0 and B > 1 else None, None)
    pos_spec = P(batch_axes if B % bt == 0 and B > 1 else None)

    pspecs = param_specs(pshapes, mesh, fsdp=_decode_params_fsdp(cfg))
    sspecs = cache_specs(sshapes, mesh,
                         replicate_model=cfg.decode_expand_kv,
                         model_on_seq=cfg.decode_cache_seq)
    logits_spec = P(tuple(tok_spec)[0], None, None)
    return LoweringPlan(
        name=f"{cfg.name}:{shape.name}:decode",
        kind="decode", cfg=cfg, step_fn=step,
        args=(pshapes, sshapes, tshape, posshape),
        in_shardings=(to_shardings(pspecs, mesh), to_shardings(sspecs, mesh),
                      NamedSharding(mesh, tok_spec), NamedSharding(mesh, pos_spec)),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       to_shardings(sspecs, mesh)),
        donate_argnums=(1,),
    )
