"""Step-function builders: baseline train step, FedDCL federated round,
prefill step, serve (decode) step. These are what dryrun.py lowers and what
train.py / serve.py execute.
"""
from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ModelConfig, TrainConfig
from repro.core.federated import (ROBUST_AGGREGATORS, robust_sync,
                                  scan_local_steps)
from repro.models import backbone as bb
from repro.optim import adamw, apply_updates, clip_by_global_norm, sgd
from repro.optim.schedules import cosine_with_warmup


def make_optimizer(tc: TrainConfig):
    sched = cosine_with_warmup(tc.learning_rate, tc.warmup_steps, tc.total_steps)
    if tc.optimizer == "sgd":
        return sgd(sched, momentum=0.9)
    return adamw(sched, weight_decay=tc.weight_decay,
                 state_dtype=jnp.dtype(tc.opt_state_dtype))


def make_train_step(cfg: ModelConfig, tc: TrainConfig, *,
                    use_pallas: bool = False) -> Tuple[Callable, Any]:
    """Baseline (non-federated) step: grads all-reduced over every data axis
    each step — the communication pattern FedDCL's round schedule amortizes."""
    opt = make_optimizer(tc)
    compute_dtype = jnp.dtype(tc.compute_dtype)

    def train_step(params, opt_state, batch):
        def lf(p):
            return bb.loss_fn(p, batch, cfg, use_pallas=use_pallas,
                              remat=tc.remat, compute_dtype=compute_dtype)

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, tc.grad_clip)
        updates, opt_state = opt.update(grads, opt_state, params)
        params = apply_updates(params, updates)
        metrics = dict(metrics)
        metrics["grad_norm"] = gnorm
        return params, opt_state, metrics

    return train_step, opt


def make_federated_local_step(cfg: ModelConfig, tc: TrainConfig, *,
                              use_pallas: bool = False) -> Tuple[Callable, Any]:
    """FedDCL outer-tier LOCAL step: the baseline step vmapped over a leading
    silo dim. With the silo dim sharded over the silo mesh axis, the lowered
    HLO contains NO collective over that axis (tests assert this) — the
    paper's 'no iterative cross-group communication' property, made
    structural. The host loop runs H of these, then one fedavg_sync_step.

    Inputs: silo_params/silo_opt_state with leading dim d; batch dict with
    leading dims (d, local_batch, ...).
    """
    local_step, opt = make_train_step(cfg, tc, use_pallas=use_pallas)

    def local_step_silo(p, o, b):
        from repro.launch.mesh import silo_axis_name
        from repro.models.moe_ep import _physical_mesh
        from repro.shardingx.constrain import silo_context
        mesh = _physical_mesh()
        axis = silo_axis_name(mesh) if mesh is not None else None
        with silo_context(axis):
            return local_step(p, o, b)

    return jax.vmap(local_step_silo), opt


def make_federated_round_step(cfg: ModelConfig, tc: TrainConfig, *,
                              use_pallas: bool = False) -> Tuple[Callable, Any]:
    """One FULL FedDCL round as a single compiled program: H silo-local
    vmapped steps run as one lax.scan (core.federated.scan_local_steps — the
    same inner loop the tabular scan engine uses) followed by the
    fedavg_sync boundary. One dispatch per round instead of H+1.

    Inputs: silo_params/silo_opt_state with leading dim d; batches with
    leading dims (H, d, local_batch, ...). Returns (params, opt_state,
    metrics stacked over H).
    """
    phase, opt = make_federated_local_phase_step(cfg, tc,
                                                 use_pallas=use_pallas)
    sync = make_fedavg_sync_step(tc)

    def round_step(silo_params, silo_opt_state, batches):
        sp, so, ms = phase(silo_params, silo_opt_state, batches)
        sp, so = sync(sp, so)
        return sp, so, ms

    return round_step, opt


def make_federated_multiround_step(cfg: ModelConfig, tc: TrainConfig, *,
                                   use_pallas: bool = False) -> Tuple[Callable, Any]:
    """R full FedDCL rounds as ONE compiled dispatch: a lax.scan over
    (local phase -> fedavg_sync) round steps. Batches carry leading dims
    (R, H, d, ...); metrics come back as (R, H) SCALARS — each leaf is
    silo-meaned inside the scan so the stacked history stays bounded
    regardless of d or metric rank (the same bounded-memory contract as the
    tabular engine's streamed eval path, DESIGN.md §7). train.py's
    --rounds-per-dispatch consumes this to amortize dispatch overhead.
    """
    round_step, opt = make_federated_round_step(cfg, tc, use_pallas=use_pallas)

    def multiround(silo_params, silo_opt_state, batches):
        def body(carry, b):
            sp, so = carry
            sp, so, ms = round_step(sp, so, b)
            scal = jax.tree.map(
                lambda a: jnp.mean(a.reshape(a.shape[0], -1), axis=1), ms)
            return (sp, so), scal

        (sp, so), ms = lax.scan(body, (silo_params, silo_opt_state), batches)
        return sp, so, ms

    return multiround, opt


def make_federated_local_phase_step(cfg: ModelConfig, tc: TrainConfig, *,
                                    use_pallas: bool = False) -> Tuple[Callable, Any]:
    """H silo-local steps as one lax.scan WITHOUT the sync boundary — the
    round step minus fedavg_sync. train.py uses it for the trailing steps of
    an unfinished round (steps % local_steps)."""
    local_step, opt = make_federated_local_step(cfg, tc, use_pallas=use_pallas)

    def phase(silo_params, silo_opt_state, batches):
        return scan_local_steps(local_step, silo_params, silo_opt_state,
                                batches)

    return phase, opt


def make_fedavg_sync_step(tc: TrainConfig) -> Callable:
    """Round boundary: aggregate params across the silo dim — the weighted
    mean (ONE all-reduce over the silo mesh axis per leaf) for the averaging
    aggregators, or the configured robust statistic (median / trimmed_mean /
    krum via robust_sync, DESIGN.md §8) — and, for the fedavg-family
    boundaries that restart local state per the paper, reset the local
    optimizer state for the next round."""
    fed = tc.federated
    def sync(silo_params, silo_opt_state):
        p = robust_sync(silo_params, fed.aggregator,
                        trim_frac=fed.trim_frac, krum_f=fed.krum_f)
        if fed.aggregator == "fedavg" or fed.aggregator in ROBUST_AGGREGATORS:
            silo_opt_state = jax.tree.map(jnp.zeros_like, silo_opt_state)
        return p, silo_opt_state

    return sync


def make_prefill_step(cfg: ModelConfig, *, cache_len: int,
                      compute_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16,
                      use_pallas: bool = False) -> Callable:
    def prefill_step(params, batch):
        logits, state, next_pos = bb.prefill(
            params, batch["tokens"], cfg, cache_len=cache_len,
            prefix_embeds=batch.get("prefix_embeds"),
            compute_dtype=compute_dtype, cache_dtype=cache_dtype,
            use_pallas=use_pallas)
        return logits, state, next_pos

    return prefill_step


def make_serve_step(cfg: ModelConfig, *, compute_dtype=jnp.bfloat16) -> Callable:
    def serve_step(params, state, tokens, cur_pos):
        return bb.decode_step(params, state, tokens, cur_pos, cfg,
                              compute_dtype=compute_dtype)

    return serve_step
