"""Checkpointing: pytree <-> flat .npz with path-encoded keys.

Handles arbitrary nested dict/list/tuple pytrees (params, optimizer states,
decode caches). Keys encode the tree path; restore rebuilds into the
structure of a provided template (so dtypes/shardings can differ from the
saved arrays and are re-imposed by the caller's device_put)."""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any, Dict

import jax
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_fmt(p) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _fmt(p) -> str:
    if isinstance(p, jax.tree_util.DictKey):
        return str(p.key)
    if isinstance(p, jax.tree_util.SequenceKey):
        return f"#{p.idx}"
    if isinstance(p, jax.tree_util.GetAttrKey):
        return str(p.name)
    return str(p)


def save(path: str, tree: Any, metadata: Dict[str, Any] | None = None) -> None:
    """Atomic save (tmp + rename).

    The tmp name carries the .npz suffix so numpy writes the very file
    mkstemp owns — savez only appends ".npz" to names missing it, and the
    old append-then-guess-rename dance raced concurrent savers on a
    predictable sibling name. Writing through the mkstemp fd keeps the
    whole tmp lifetime under names no other process can collide with.
    """
    flat = _flatten(tree)
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)),
                               suffix=".npz")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __meta__=json.dumps(metadata or {}), **flat)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def load(path: str, template: Any) -> Any:
    """Restore into the structure of `template` (dtype of saved arrays)."""
    with np.load(path, allow_pickle=False) as zf:
        flat = {k: zf[k] for k in zf.files if k != "__meta__"}
    paths_leaves, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl_leaf in paths_leaves:
        key = _SEP.join(_fmt(p) for p in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing {key!r}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(np.shape(tmpl_leaf)):
            raise ValueError(
                f"shape mismatch for {key}: ckpt {arr.shape} vs template "
                f"{np.shape(tmpl_leaf)}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def load_metadata(path: str) -> Dict[str, Any]:
    with np.load(path, allow_pickle=False) as zf:
        if "__meta__" in zf.files:
            return json.loads(str(zf["__meta__"]))
    return {}
