"""Compiled-artifact auditor: enforce the privacy and performance
invariants on the EXECUTABLE, not just the source (DESIGN.md §9).

Three tools, each generalizing a check that previously lived as ad-hoc
code inside individual tests:

`collective_census(lowered)` — the collective-op histogram of a compiled
    module. A sharded weighted plan must hold exactly
    {all-reduce: leaves+1} per hierarchy level, a robust plan
    {all-reduce: 1, all-gather: leaves+1}, and an UNSHARDED plan no
    collective at all (tests/test_fed_sharded.py, tests/test_fed_robust.py,
    benchmarks/fed_bench.py --sharded all consume this one function now).

`assert_no_baked_data(lowered)` — the artifact-level privacy check. Before
    data-as-arguments plans (PR 3) the jitted runner closed over tenant
    arrays and XLA baked them into the executable as large dense
    constants: raw silo data INSIDE the compiled artifact, the exact
    non-sharing guarantee FedDCL exists to provide (arXiv 2409.18356)
    broken where no source-level review would see it. This walks the
    lowered StableHLO for large non-splat constants and raises
    `BakedDataError` naming them. Splat constants (zeros/ones fills from
    padding or init) carry no information and pass at any size.

`CompileCounter` — a recompile sentinel: counts XLA backend compilations
    inside a `with` block by hooking `jax._src.compiler.backend_compile`.
    Warm-path tests assert `count == 0` directly instead of inferring
    "no recompile" from a 29–60× timing ratio that goes flaky on loaded
    CI runners (tests/test_plan_cache.py).
"""
from __future__ import annotations

import re
from typing import Any, Dict, List, Optional, Tuple

COLLECTIVE_KINDS = ("all-reduce", "all-gather", "all-to-all",
                    "collective-permute", "reduce-scatter")


def _as_compiled_text(lowered: Any) -> str:
    """Compiled-HLO text from a jax Lowered/Compiled/str. Async collective
    forms appear post-compile, so the census always counts the compiled
    module (what actually runs), not the StableHLO input."""
    if isinstance(lowered, str):
        return lowered
    if hasattr(lowered, "compile"):           # jax.stages.Lowered
        lowered = lowered.compile()
    if hasattr(lowered, "as_text"):           # jax.stages.Compiled
        return lowered.as_text()
    raise TypeError(
        f"expected a jax Lowered/Compiled or HLO text, got {type(lowered)}")


def collective_census(lowered: Any,
                      kinds: Tuple[str, ...] = COLLECTIVE_KINDS
                      ) -> Dict[str, int]:
    """Histogram of collective ops in a compiled module, keyed by kind,
    zero-count kinds omitted. Async `-start` forms count once (`-done`
    lines don't match, so start/done pairs aren't double-counted) — the
    exact counting rule the sharded tests pinned their asserted counts
    with, now in one place."""
    txt = _as_compiled_text(lowered)
    out: Dict[str, int] = {}
    for kind in kinds:
        n = len(re.findall(rf"= \S+ {kind}(?:-start)?\(", txt))
        if n:
            out[kind] = n
    return out


class BakedDataError(AssertionError):
    """The lowered program embeds a large dense constant — tenant data (or
    another runtime-sized array) was captured by closure and baked into
    the executable instead of entering as an argument."""


def _stablehlo_text(lowered: Any) -> str:
    if isinstance(lowered, str):
        return lowered
    if hasattr(lowered, "as_text"):           # Lowered: StableHLO pre-compile
        return lowered.as_text()
    raise TypeError(
        f"expected a jax Lowered or StableHLO text, got {type(lowered)}")


_CONST_RE = re.compile(
    r"(?:stablehlo\.constant|mhlo\.constant)\s+"
    r"(dense<[^>]*>|dense_resource<[^>]*>)\s*:\s*tensor<([^>]*)>")


def _tensor_elems(tensor_sig: str) -> Tuple[int, str]:
    """("64x32xf32") -> (2048, "f32"); scalar signatures have no dims."""
    parts = tensor_sig.split("x")
    dims = [p for p in parts if p.isdigit()]
    dtype = parts[-1]
    n = 1
    for d in dims:
        n *= int(d)
    return n, dtype


def find_baked_constants(lowered: Any, min_elems: int = 1024
                         ) -> List[Dict[str, Any]]:
    """Large NON-SPLAT dense constants in the lowered StableHLO.

    A splat (`dense<0.0e+00> : tensor<128x64xf32>`) encodes one value —
    a padding/init fill, not data. A non-splat literal (an element list
    `dense<[...]>`, a raw hex blob `dense<"0x...">`, or an elided
    `dense_resource<...>` — MLIR elides literals precisely because they
    are big) of `min_elems` or more elements is a baked array."""
    txt = _stablehlo_text(lowered)
    found: List[Dict[str, Any]] = []
    for m in _CONST_RE.finditer(txt):
        literal, sig = m.group(1), m.group(2)
        body = literal[literal.index("<") + 1:-1]
        non_splat = (literal.startswith("dense_resource")
                     or body.startswith("[") or body.startswith('"'))
        if not non_splat:
            continue
        elems, dtype = _tensor_elems(sig)
        if elems >= min_elems:
            found.append({"elements": elems, "dtype": dtype,
                          "type": f"tensor<{sig}>",
                          "literal_head": literal[:48]})
    return found


def assert_no_baked_data(lowered: Any, min_elems: int = 1024) -> None:
    """Raise `BakedDataError` if the lowered program embeds any non-splat
    dense constant of >= min_elems elements — the PR 3 artifact-level
    privacy leak (tenant arrays inside the compiled plan). Passing means:
    every runtime-sized array reaches the executable as an ARGUMENT."""
    baked = find_baked_constants(lowered, min_elems=min_elems)
    if baked:
        detail = ", ".join(
            f"{b['type']} ({b['elements']} elems)" for b in baked[:8])
        raise BakedDataError(
            f"lowered program embeds {len(baked)} dense constant(s) of "
            f">={min_elems} elements: {detail} — data captured by closure "
            "is baked into the executable (the non-sharing guarantee "
            "broken at the artifact level); pass arrays as plan arguments "
            "(core/federated.make_fl_plan)")


class CompileCounter:
    """Count XLA backend compilations inside a `with` block.

    Hooks `jax._src.compiler.backend_compile` — the single funnel every
    fresh executable build passes through in jax 0.4.x (jit C++ cache
    hits, plan-cache hits, and persistent-compilation-cache disk hits all
    bypass it). `count == 0` therefore IS "the warm path rebuilt
    nothing", with none of the timing-ratio flakiness. Reentrant
    `with` blocks nest; the hook is removed on exit even on error."""

    def __init__(self) -> None:
        self.count = 0
        self._orig = None

    def __enter__(self) -> "CompileCounter":
        import jax._src.compiler as _compiler
        self._compiler = _compiler
        self._orig = _compiler.backend_compile
        orig = self._orig

        def counting_backend_compile(*args, **kwargs):
            self.count += 1
            return orig(*args, **kwargs)

        _compiler.backend_compile = counting_backend_compile
        return self

    def __exit__(self, *exc) -> None:
        self._compiler.backend_compile = self._orig
        self._orig = None
        return None
