"""AST lint: each rule encodes one regression this repo actually shipped.

The invariants below were all discovered the hard way (CHANGES.md PR 3–5)
and, until this tier existed, lived only as prose plus ad-hoc string
assertions inside individual tests. The linter makes them machine-checked
over `src/`, `benchmarks/`, `experiments/`, `examples/`, and `scripts/`
(DESIGN.md §9 maps each rule to the PR that fixed the original bug):

  R001  `time.time()` in a perf path — wall clock jumps under NTP slew;
        timing must use `time.perf_counter()` (PR 5 swept these).
  R002  builtin `hash()` for seeds/keys — str hashing is salted per
        process, so "deterministic" seeds differ between runs (PR 1,
        data/tabular.py; use zlib.crc32 or an explicit integer mix).
  R003  global-state `np.random.*` (seed/rand/randn/…) — cross-module
        draw-order coupling; use `np.random.default_rng(seed)` or
        jax fold_in streams.
  R004  a jitted function closing over an ndarray/jax.Array — the data is
        baked into the executable as an HLO constant: uncacheable AND the
        artifact-level privacy leak of PR 3 (tenant data inside the
        compiled plan). Data must enter as arguments (`make_fl_plan`).
  R005  float32 casts on sample counts/sizes — float32 collapses integers
        above 2^24, silently corrupting FedAvg weights (PR 3; counts stay
        integral, normalize in float64, cast only the normalized result).
  R006  dividing by a weight-mass sum without a tiny-eps guard — the old
        `max(Σw, 1)` clamp silently deflated losses at fractional weight
        mass (PR 5, `_DEN_EPS`); a bare `/ w.sum()` NaNs at zero mass.
  R007  `np.save*` checkpoint writes not going through `mkstemp` —
        guess-renamed sibling names raced concurrent savers (PR 3,
        checkpoint/store.py).
  R008  `device_get` / `block_until_ready` inside a lax.scan body or a
        per-round loop — a host sync per round re-serializes the engine
        the scan work collapsed into one dispatch (PR 4 streams ONE
        transfer per eval chunk instead).

Allowlisting: a deliberate exception carries a trailing (or
immediately-preceding-line) comment

    # feddcl-lint: disable=R008  <why this site is allowed>

and a whole file can opt out of a rule with

    # feddcl-lint: disable-file=R003  <why>

The disable comment is the audit trail: the justification text rides in
the source next to the exception.

Pure stdlib (ast + re) — importable without jax, so the CLI
(`scripts/feddcl_lint.py`) runs anywhere, including bare CI runners.
"""
from __future__ import annotations

import ast
import json
import os
import re
from dataclasses import asdict, dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

RULES: Dict[str, str] = {
    "R001": "time.time() used where perf_counter is required "
            "(wall clock is not monotonic)",
    "R002": "builtin hash() used for seeding/keys "
            "(str hashing is salted per process)",
    "R003": "global-state np.random.* call "
            "(use np.random.default_rng / jax fold_in streams)",
    "R004": "jitted function closes over an array "
            "(data baked into the executable — pass it as an argument)",
    "R005": "float32 cast on a sample count/size "
            "(float32 collapses integers above 2^24)",
    "R006": "division by a weight-mass sum without a tiny-eps guard "
            "(use jnp.maximum(sum, eps<=1e-6), cf. _DEN_EPS)",
    "R007": "np.save*/checkpoint write not going through tempfile.mkstemp "
            "(non-atomic writes race concurrent savers)",
    "R008": "device_get/block_until_ready inside a scan body or per-round "
            "loop (a host sync per round re-serializes the compiled phase)",
}

_DISABLE_RE = re.compile(
    r"#\s*feddcl-lint:\s*disable=([A-Za-z0-9_,\s]+?)(?:\s{2,}|#|$)")
_DISABLE_FILE_RE = re.compile(
    r"#\s*feddcl-lint:\s*disable-file=([A-Za-z0-9_,\s]+?)(?:\s{2,}|#|$)")

# R003: the np.random module-level functions that mutate the hidden global
# RandomState. Constructors of explicit generators are fine.
_NP_GLOBAL_RANDOM = {
    "seed", "random", "rand", "randn", "randint", "random_sample", "ranf",
    "sample", "normal", "standard_normal", "uniform", "choice",
    "permutation", "shuffle", "binomial", "poisson", "beta", "gamma",
    "exponential", "lognormal", "laplace", "multivariate_normal",
    "get_state", "set_state", "random_integers", "bytes", "dirichlet",
}

# R004: calls whose result is (almost certainly) a host or device array.
_ARRAY_CONSTRUCTORS = {
    "numpy.array", "numpy.asarray", "numpy.zeros", "numpy.ones",
    "numpy.full", "numpy.arange", "numpy.linspace", "numpy.empty",
    "numpy.eye", "numpy.stack", "numpy.concatenate", "numpy.load",
    "jax.numpy.array", "jax.numpy.asarray", "jax.numpy.zeros",
    "jax.numpy.ones", "jax.numpy.full", "jax.numpy.arange",
    "jax.numpy.linspace", "jax.numpy.eye", "jax.numpy.stack",
    "jax.numpy.concatenate", "jax.device_put",
}
# ... and generator draw methods (rng.standard_normal(...) etc.)
_ARRAY_METHODS = {
    "standard_normal", "normal", "random", "uniform", "integers",
    "choice", "permutation",
}

# R005: identifiers that name sample counts/sizes.
_COUNTY_RE = re.compile(r"(size|sizes|count|counts|n_samples|num_samples)",
                        re.IGNORECASE)

# R006: identifiers that name sample-weight / mask vectors.
_WEIGHTY = {"w", "ws", "wb", "wn", "wr", "mask", "masks", "weights"}
_WEIGHTY_RE = re.compile(r"(weight|mass)", re.IGNORECASE)

# R008: loop headers that advance federated rounds.
_ROUNDY_RE = re.compile(r"(round|rnd)", re.IGNORECASE)

_F32_NAMES = {"numpy.float32", "jax.numpy.float32"}


@dataclass
class Violation:
    rule: str
    path: str
    line: int
    col: int
    message: str
    snippet: str = ""

    def format(self) -> str:
        return (f"{self.path}:{self.line}:{self.col}: {self.rule} "
                f"{self.message}" + (f"  [{self.snippet}]" if self.snippet
                                     else ""))


def _parse_disables(source: str) -> Tuple[Dict[int, Set[str]], Set[str]]:
    """Per-line and file-level `# feddcl-lint: disable=` directives."""
    per_line: Dict[int, Set[str]] = {}
    whole_file: Set[str] = set()
    for i, text in enumerate(source.splitlines(), start=1):
        m = _DISABLE_FILE_RE.search(text)
        if m:
            whole_file |= {r.strip().upper()
                           for r in m.group(1).split(",") if r.strip()}
            continue
        m = _DISABLE_RE.search(text)
        if m:
            per_line[i] = {r.strip().upper()
                           for r in m.group(1).split(",") if r.strip()}
    return per_line, whole_file


class _Scope:
    """One lexical function/module scope: names bound here, array-valued
    names bound here, and functions defined here (for jit(f) resolution)."""

    def __init__(self, node: Optional[ast.AST]) -> None:
        self.node = node
        self.bound: Set[str] = set()
        self.arrays: Set[str] = set()
        self.functions: Dict[str, ast.AST] = {}


class _Linter(ast.NodeVisitor):
    def __init__(self, source: str, path: str) -> None:
        self.path = path
        self.lines = source.splitlines()
        self.aliases: Dict[str, str] = {}     # local name -> dotted module path
        self.scopes: List[_Scope] = []
        self.violations: List[Violation] = []
        # R008 context flags
        self._round_loop_depth = 0
        self._scan_bodies: Set[ast.AST] = set()
        self._in_scan_body = 0
        # R007: function nodes that call mkstemp somewhere inside
        self._mkstemp_funcs: Set[ast.AST] = set()

    # ---------------------------------------------------------------- utils

    def _emit(self, rule: str, node: ast.AST, message: str) -> None:
        line = getattr(node, "lineno", 0)
        snippet = self.lines[line - 1].strip() if 0 < line <= len(self.lines) \
            else ""
        self.violations.append(Violation(
            rule=rule, path=self.path, line=line,
            col=getattr(node, "col_offset", 0) + 1, message=message,
            snippet=snippet[:120]))

    def _dotted(self, node: ast.AST) -> Optional[str]:
        """Resolve an attribute chain through the import aliases:
        `np.random.seed` -> "numpy.random.seed"."""
        parts: List[str] = []
        while isinstance(node, ast.Attribute):
            parts.append(node.attr)
            node = node.value
        if not isinstance(node, ast.Name):
            return None
        head = self.aliases.get(node.id, node.id)
        return ".".join([head] + list(reversed(parts)))

    def _idents(self, node: ast.AST) -> List[str]:
        out = []
        for n in ast.walk(node):
            if isinstance(n, ast.Name):
                out.append(n.id)
            elif isinstance(n, ast.Attribute):
                out.append(n.attr)
        return out

    # ------------------------------------------------------------- imports

    def visit_Import(self, node: ast.Import) -> None:
        for a in node.names:
            self.aliases[a.asname or a.name.split(".")[0]] = \
                a.name if a.asname else a.name.split(".")[0]
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module:
            for a in node.names:
                self.aliases[a.asname or a.name] = f"{node.module}.{a.name}"
        self.generic_visit(node)

    # ------------------------------------------------------------ scoping

    def visit_Module(self, node: ast.Module) -> None:
        # aliases first: the prescan below resolves jnp.asarray & co., so
        # module-level `data = jnp.asarray(...)` must already see the
        # import table (imports textually follow nothing at module level,
        # but the prescan walks assignments before generic_visit reaches
        # the Import nodes)
        for sub in ast.walk(node):
            if isinstance(sub, ast.Import):
                self.visit_Import(sub)
            elif isinstance(sub, ast.ImportFrom):
                self.visit_ImportFrom(sub)
        self.scopes.append(_Scope(node))
        self._prescan(node)
        self.generic_visit(node)
        self.scopes.pop()

    def _prescan(self, node: ast.AST) -> None:
        """Record this scope's array-valued assignments and local function
        defs (one pass ahead of the main walk, so forward references and
        `jit(f)`-after-def both resolve). Walks THIS scope only: nested
        function/lambda bodies are their own scopes, prescanned on entry."""
        scope = self.scopes[-1]
        body = getattr(node, "body", None)
        if not isinstance(body, list):       # Lambda: single expression
            return
        stack: List[ast.AST] = list(body)
        while stack:
            n = stack.pop()
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scope.functions.setdefault(n.name, n)
                continue                     # nested scope: don't descend
            if isinstance(n, ast.Lambda):
                continue
            if isinstance(n, ast.Assign) and self._is_array_expr(n.value):
                for tgt in n.targets:
                    if isinstance(tgt, ast.Name):
                        scope.arrays.add(tgt.id)
            elif isinstance(n, ast.AnnAssign) and n.value is not None and \
                    self._is_array_expr(n.value) and \
                    isinstance(n.target, ast.Name):
                scope.arrays.add(n.target.id)
            stack.extend(ast.iter_child_nodes(n))

    def _is_array_expr(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Call):
            dotted = self._dotted(node.func)
            if dotted in _ARRAY_CONSTRUCTORS:
                return True
            if isinstance(node.func, ast.Attribute) and \
                    node.func.attr in _ARRAY_METHODS:
                return True
        if isinstance(node, ast.Subscript):
            return self._is_array_expr(node.value)
        if isinstance(node, ast.BinOp):
            return self._is_array_expr(node.left) or \
                self._is_array_expr(node.right)
        return False

    def _enter_function(self, node) -> None:
        scope = _Scope(node)
        args = node.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs +
                  ([args.vararg] if args.vararg else []) +
                  ([args.kwarg] if args.kwarg else [])):
            scope.bound.add(a.arg)
        self.scopes.append(scope)
        self._prescan(node)

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self.scopes[-1].bound.add(node.name)
        if any(self._is_jit_decorator(d) for d in node.decorator_list):
            self._check_jit_closure(node)
        if self._calls_mkstemp(node):
            self._mkstemp_funcs.add(node)
        self._enter_function(node)
        self.generic_visit(node)
        self.scopes.pop()

    visit_AsyncFunctionDef = visit_FunctionDef

    def visit_Lambda(self, node: ast.Lambda) -> None:
        self._enter_function(node)
        self.generic_visit(node)
        self.scopes.pop()

    def visit_Assign(self, node: ast.Assign) -> None:
        for tgt in node.targets:
            for n in ast.walk(tgt):
                if isinstance(n, ast.Name):
                    self.scopes[-1].bound.add(n.id)
        self.generic_visit(node)

    def _calls_mkstemp(self, node: ast.AST) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                dotted = self._dotted(sub.func)
                if dotted and dotted.split(".")[-1] in ("mkstemp",
                                                        "NamedTemporaryFile"):
                    return True
        return False

    # -------------------------------------------------- R004 (jit closure)

    def _is_jit_name(self, node: ast.AST) -> bool:
        dotted = self._dotted(node)
        return dotted in ("jax.jit", "jit", "jax.pjit", "pjit") or (
            dotted is not None and dotted.endswith(".jit"))

    def _is_jit_decorator(self, dec: ast.AST) -> bool:
        if isinstance(dec, ast.Call):
            return self._is_jit_name(dec.func)
        return self._is_jit_name(dec)

    def _free_array_captures(self, fn) -> List[str]:
        """Names the function loads but does not bind, that an enclosing
        scope binds to an array value."""
        bound: Set[str] = set()
        args = fn.args
        for a in (args.posonlyargs + args.args + args.kwonlyargs +
                  ([args.vararg] if args.vararg else []) +
                  ([args.kwarg] if args.kwarg else [])):
            bound.add(a.arg)
        loads: List[str] = []
        body = fn.body if isinstance(fn.body, list) else [fn.body]
        for stmt in body:
            for sub in ast.walk(stmt):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    bound.add(sub.name)
                if isinstance(sub, ast.Name):
                    if isinstance(sub.ctx, (ast.Store, ast.Del)):
                        bound.add(sub.id)
                    else:
                        loads.append(sub.id)
                elif isinstance(sub, (ast.comprehension,)):
                    for n in ast.walk(sub.target):
                        if isinstance(n, ast.Name):
                            bound.add(n.id)
        captured: List[str] = []
        enclosing_arrays: Set[str] = set()
        for scope in self.scopes:
            enclosing_arrays |= scope.arrays
        for name in loads:
            if name not in bound and name in enclosing_arrays and \
                    name not in captured:
                captured.append(name)
        return captured

    def _check_jit_closure(self, fn, at: Optional[ast.AST] = None) -> None:
        for name in self._free_array_captures(fn):
            self._emit(
                "R004", at or fn,
                f"jitted function closes over array {name!r} — the value is "
                "baked into the compiled executable as a constant "
                "(uncacheable; leaks the data into the artifact). Pass it "
                "as an argument instead")

    # ----------------------------------------------------------- R008 ctx

    def _is_round_loop(self, node) -> bool:
        header = node.iter if isinstance(node, ast.For) else node.test
        idents = self._idents(header)
        if isinstance(node, ast.For):
            for n in ast.walk(node.target):
                if isinstance(n, ast.Name):
                    idents.append(n.id)
        return any(_ROUNDY_RE.search(i) for i in idents)

    def visit_For(self, node: ast.For) -> None:
        self._visit_loop(node)

    def visit_While(self, node: ast.While) -> None:
        self._visit_loop(node)

    def _visit_loop(self, node) -> None:
        roundy = self._is_round_loop(node)
        if roundy:
            self._round_loop_depth += 1
        if isinstance(node, ast.For):
            for n in ast.walk(node.target):
                if isinstance(n, ast.Name):
                    self.scopes[-1].bound.add(n.id)
        self.generic_visit(node)
        if roundy:
            self._round_loop_depth -= 1

    # ------------------------------------------------------------- calls

    def visit_Call(self, node: ast.Call) -> None:
        dotted = self._dotted(node.func)
        last = dotted.split(".")[-1] if dotted else (
            node.func.attr if isinstance(node.func, ast.Attribute) else None)

        # R001 — wall clock
        if dotted == "time.time":
            self._emit("R001", node,
                       "time.time() is not monotonic — use "
                       "time.perf_counter() for timing")

        # R002 — salted builtin hash for seeds/keys
        if isinstance(node.func, ast.Name) and node.func.id == "hash" and \
                "hash" not in self._all_bound():
            self._emit("R002", node,
                       "builtin hash() is salted per process — derive "
                       "seeds/keys with zlib.crc32 or an integer mix")

        # R003 — global-state numpy RNG
        if dotted and dotted.startswith("numpy.random.") and \
                dotted.split(".")[-1] in _NP_GLOBAL_RANDOM:
            self._emit("R003", node,
                       f"np.random.{dotted.split('.')[-1]} mutates the "
                       "hidden global RandomState — use "
                       "np.random.default_rng(seed)")

        # R004 — jit(f) / jit(lambda …) wrapping forms
        if self._is_jit_name(node.func) and node.args:
            target = node.args[0]
            if isinstance(target, ast.Lambda):
                self._check_jit_closure(target, at=node)
            elif isinstance(target, ast.Name):
                for scope in reversed(self.scopes):
                    fn = scope.functions.get(target.id)
                    if fn is not None:
                        self._check_jit_closure(fn, at=node)
                        break

        # R005 — float32 on counts
        self._check_r005(node, dotted, last)

        # R007 — raw checkpoint writes
        if dotted in ("numpy.save", "numpy.savez", "numpy.savez_compressed"):
            if not self._enclosing_mkstemp():
                self._emit("R007", node,
                           f"{dotted.replace('numpy', 'np')} writes the "
                           "target path directly — write via a "
                           "tempfile.mkstemp fd and os.replace into place "
                           "(checkpoint/store.py is the pattern)")

        # R008 — host syncs inside round loops / scan bodies
        if last in ("device_get", "block_until_ready") and (
                self._round_loop_depth > 0 or self._in_scan_body > 0):
            where = "a lax.scan body" if self._in_scan_body else \
                "a per-round loop"
            self._emit("R008", node,
                       f"{last} inside {where} forces one host sync per "
                       "round — batch transfers per chunk instead "
                       "(StreamedPlan streams ONE device_get per chunk)")

        # collect scan bodies for R008: lax.scan(body_fn, ...)
        if dotted and dotted.split(".")[-1] == "scan" and node.args and \
                isinstance(node.args[0], ast.Name):
            for scope in reversed(self.scopes):
                fn = scope.functions.get(node.args[0].id)
                if fn is not None:
                    self._flag_scan_body(fn)
                    break

        self.generic_visit(node)

    def _flag_scan_body(self, fn: ast.AST) -> None:
        if fn in self._scan_bodies:
            return
        self._scan_bodies.add(fn)
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call):
                last = None
                if isinstance(sub.func, ast.Attribute):
                    last = sub.func.attr
                elif isinstance(sub.func, ast.Name):
                    last = sub.func.id
                if last in ("device_get", "block_until_ready"):
                    self._emit("R008", sub,
                               f"{last} inside a lax.scan body forces a "
                               "host sync per scan step")

    def _all_bound(self) -> Set[str]:
        out: Set[str] = set()
        for s in self.scopes:
            out |= s.bound
        return out

    def _enclosing_mkstemp(self) -> bool:
        for scope in reversed(self.scopes):
            if scope.node in self._mkstemp_funcs:
                return True
            if isinstance(scope.node, ast.Module):
                # module-level write: accept a module-level mkstemp call
                return self._calls_mkstemp(scope.node)
        return False

    # ----------------------------------------------------------- R005/R006

    def _county(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name) and _COUNTY_RE.search(node.id):
            return node.id
        if isinstance(node, ast.Attribute) and _COUNTY_RE.search(node.attr):
            return node.attr
        return None

    def _is_f32(self, node: ast.AST) -> bool:
        if isinstance(node, ast.Constant) and node.value == "float32":
            return True
        return self._dotted(node) in _F32_NAMES

    def _check_r005(self, node: ast.Call, dotted: Optional[str],
                    last: Optional[str]) -> None:
        flag: Optional[str] = None
        # x.astype(np.float32)
        if isinstance(node.func, ast.Attribute) and \
                node.func.attr == "astype" and node.args and \
                self._is_f32(node.args[0]):
            flag = self._county(node.func.value)
        # np.float32(x)
        elif dotted in _F32_NAMES and node.args:
            flag = self._county(node.args[0])
        # np.asarray(x, np.float32) / np.array(x, dtype=np.float32)
        elif dotted in ("numpy.asarray", "numpy.array", "jax.numpy.asarray",
                        "jax.numpy.array") and node.args:
            dt = node.args[1] if len(node.args) > 1 else next(
                (k.value for k in node.keywords if k.arg == "dtype"), None)
            if dt is not None and self._is_f32(dt):
                flag = self._county(node.args[0])
        if flag:
            self._emit("R005", node,
                       f"float32 cast on sample count {flag!r} — float32 "
                       "collapses integers above 2^24; keep counts "
                       "integral, normalize in float64, cast the "
                       "normalized result")

    def _weighty(self, node: ast.AST) -> Optional[str]:
        if isinstance(node, ast.Name) and (
                node.id in _WEIGHTY or _WEIGHTY_RE.search(node.id)):
            return node.id
        if isinstance(node, ast.Attribute) and (
                node.attr in _WEIGHTY or _WEIGHTY_RE.search(node.attr)):
            return node.attr
        return None

    def _weight_sum(self, node: ast.AST) -> Optional[str]:
        """Is this expression a sum over a weight/mask vector?"""
        if not isinstance(node, ast.Call):
            return None
        # np.sum(w) / jnp.sum(w) / sum(w) — check the argument form first:
        # jnp.sum(weights) also parses as <receiver>.sum(), and the receiver
        # (a module alias) is never weighty, so the attribute form must not
        # preempt it
        dotted = self._dotted(node.func)
        if dotted and dotted.split(".")[-1] == "sum" and node.args:
            got = self._weighty(node.args[0])
            if got is not None:
                return got
        if isinstance(node.func, ast.Name) and node.func.id == "sum" and \
                node.args:
            got = self._weighty(node.args[0])
            if got is not None:
                return got
        # w.sum() / w.sum(axis=...)
        if isinstance(node.func, ast.Attribute) and node.func.attr == "sum":
            return self._weighty(node.func.value)
        return None

    def visit_BinOp(self, node: ast.BinOp) -> None:
        if isinstance(node.op, ast.Div):
            den = node.right
            name = self._weight_sum(den)
            if name is not None:
                self._emit(
                    "R006", node,
                    f"division by sum({name}) without an eps guard — zero "
                    "weight mass NaNs; wrap as maximum(sum, eps<=1e-6) "
                    "(cf. federated._DEN_EPS)")
            else:
                # maximum(sum(w), BIG): the PR 5 deflation bug — a clamp
                # constant >= 1 silently deflates at fractional mass
                guard = self._guarded_weight_sum(den)
                if guard is not None:
                    gname, eps = guard
                    if eps is not None and eps > 1e-6:
                        self._emit(
                            "R006", node,
                            f"maximum(sum({gname}), {eps!r}) deflates the "
                            "mean whenever the real weight mass is below "
                            f"{eps!r} — use a tiny eps (<=1e-6, cf. "
                            "federated._DEN_EPS)")
        self.generic_visit(node)

    def _guarded_weight_sum(self, node: ast.AST):
        """maximum(sum(w), c) → (name, c) with c=None for non-constant."""
        if not (isinstance(node, ast.Call) and len(node.args) == 2):
            return None
        dotted = self._dotted(node.func)
        last = dotted.split(".")[-1] if dotted else (
            node.func.id if isinstance(node.func, ast.Name) else None)
        if last not in ("maximum", "max"):
            return None
        name = self._weight_sum(node.args[0])
        if name is None:
            return None
        c = node.args[1]
        eps = float(c.value) if isinstance(c, ast.Constant) and \
            isinstance(c.value, (int, float)) else None
        return name, eps


def lint_source(source: str, path: str = "<string>") -> List[Violation]:
    """Lint one module's source; returns the violations that survive the
    `# feddcl-lint: disable=` directives."""
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Violation(rule="E000", path=path, line=e.lineno or 0,
                          col=e.offset or 0,
                          message=f"syntax error: {e.msg}")]
    linter = _Linter(source, path)
    linter.visit(tree)
    per_line, whole_file = _parse_disables(source)
    out = []
    for v in linter.violations:
        if v.rule in whole_file or "ALL" in whole_file:
            continue
        rules_here = per_line.get(v.line, set()) | per_line.get(v.line - 1,
                                                               set())
        if v.rule in rules_here or "ALL" in rules_here:
            continue
        out.append(v)
    return out


def lint_file(path: str) -> List[Violation]:
    with open(path, encoding="utf-8") as f:
        return lint_source(f.read(), path)


_SKIP_DIRS = {"__pycache__", ".git", ".claude", "results", "node_modules"}


def iter_python_files(roots: Sequence[str]) -> Iterable[str]:
    for root in roots:
        if os.path.isfile(root):
            if root.endswith(".py"):
                yield root
            continue
        for dirpath, dirnames, filenames in os.walk(root):
            dirnames[:] = sorted(d for d in dirnames if d not in _SKIP_DIRS)
            for fn in sorted(filenames):
                if fn.endswith(".py"):
                    yield os.path.join(dirpath, fn)


def lint_paths(roots: Sequence[str]) -> List[Violation]:
    out: List[Violation] = []
    for path in iter_python_files(roots):
        out.extend(lint_file(path))
    return out


def violations_json(violations: Sequence[Violation],
                    files_checked: int = 0) -> str:
    return json.dumps({
        "tool": "feddcl_lint",
        "rules": RULES,
        "files_checked": files_checked,
        "violation_count": len(violations),
        "violations": [asdict(v) for v in violations],
    }, indent=1)
