"""Static analysis & artifact auditing (DESIGN.md §9).

Two layers guard the repo's hard-won invariants:

- `repro.analysis.lint` — stdlib-only AST lint (rules R001–R008, each one
  a past regression), driven by `scripts/feddcl_lint.py`.
- `repro.analysis.hlo_audit` — compiled-artifact auditor: collective
  census, the baked-tenant-data privacy check, and the CompileCounter
  recompile sentinel (imports jax; loaded lazily so the linter stays
  importable on bare runners).
"""
from repro.analysis.lint import (RULES, Violation, lint_file, lint_paths,
                                 lint_source, violations_json)

__all__ = [
    "RULES", "Violation", "lint_file", "lint_paths", "lint_source",
    "violations_json",
    "COLLECTIVE_KINDS", "BakedDataError", "CompileCounter",
    "assert_no_baked_data", "collective_census", "find_baked_constants",
]

_HLO_NAMES = {"COLLECTIVE_KINDS", "BakedDataError", "CompileCounter",
              "assert_no_baked_data", "collective_census",
              "find_baked_constants"}


def __getattr__(name):
    # hlo_audit imports jax at module load; defer so `import repro.analysis`
    # (and the lint CLI) works on runners without jax installed
    if name in _HLO_NAMES:
        from repro.analysis import hlo_audit
        return getattr(hlo_audit, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
