"""Multi-tenant collaboration serving (DESIGN.md §10): heterogeneous
x → f_j(x) G_j → h requests queued, bucketed by (group, pow2 batch width),
and served by one resident jitted batch step per shape bucket through the
shared PlanCache — plus incremental onboarding of users/silos onto a live
server."""
from repro.serve_collab.server import (CollabRequest, ServeCollab,
                                       ServeOutput, serve_step)
from repro.serve_collab.tables import (TenantTable, build_table,
                                       build_tables, combined_user_map)

__all__ = [
    "CollabRequest", "ServeCollab", "ServeOutput", "serve_step",
    "TenantTable", "build_table", "build_tables", "combined_user_map",
]
