"""Device-resident tenant tables for collaboration serving (DESIGN.md §10).

After FedDCL setup, user (i, j)'s whole input pipeline collapses to ONE
affine map: f_j(x) G_j = (x − mu_j) (W_j G_j). A group's tenants therefore
serve from two stacked arrays

    M  (T_pad, m, m̂)   combined per-tenant maps  W_j @ G_j
    mu (T_pad, m)       per-tenant centering offsets

zero-padded on the tenant axis to the next power of two, so onboarding a
tenant usually lands in the existing padded shape (the resident batch step
never recompiles) and at worst doubles it (one fresh bucket). The tables
are ARGUMENTS of the jitted serve step, never closure captures — tenant
data stays out of the executable (analysis.hlo_audit.assert_no_baked_data
enforces this on the artifact).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Sequence

import jax.numpy as jnp
import numpy as np

from repro.core.federated import bucket_pow2
from repro.core.protocol import FedDCLSetup


@dataclass
class TenantTable:
    """One group's resident serving state."""
    M: jnp.ndarray                    # (T_pad, m, m_hat) float32
    mu: jnp.ndarray                   # (T_pad, m) float32
    count: int                        # real tenants; rows past it are zeros

    @property
    def t_pad(self) -> int:
        return int(self.M.shape[0])

    @property
    def in_dim(self) -> int:
        return int(self.M.shape[1])

    @property
    def out_dim(self) -> int:
        return int(self.M.shape[2])


def combined_user_map(setup: FedDCLSetup, i: int, j: int) -> np.ndarray:
    """W_j^(i) @ G_j^(i) — the (m, m̂) matrix user (i,j) serves through."""
    return np.asarray(setup.mappings[i][j].W, np.float64) @ np.asarray(
        setup.Gs[i][j], np.float64)


def build_table(setup: FedDCLSetup, i: int,
                bucket: Callable[[int], int] = bucket_pow2) -> TenantTable:
    """Stack group i's tenants into one padded device-resident table."""
    count = len(setup.mappings[i])
    m = setup.mappings[i][0].W.shape[0]
    m_hat = np.asarray(setup.Gs[i][0]).shape[1]
    t_pad = bucket(count)
    M = np.zeros((t_pad, m, m_hat), np.float32)
    mu = np.zeros((t_pad, m), np.float32)
    for j in range(count):
        M[j] = combined_user_map(setup, i, j).astype(np.float32)
        mu[j] = np.asarray(setup.mappings[i][j].mu, np.float32)
    return TenantTable(M=jnp.asarray(M), mu=jnp.asarray(mu), count=count)


def build_tables(setup: FedDCLSetup,
                 bucket: Callable[[int], int] = bucket_pow2
                 ) -> List[TenantTable]:
    """One table per DC group."""
    return [build_table(setup, i, bucket) for i in range(setup.num_groups)]
