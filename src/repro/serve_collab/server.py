"""Multi-tenant online inference over the collaboration pipeline
(DESIGN.md §10) — the plan cache's first live consumer.

Heterogeneous prediction requests (any tenant, any row count) share ONE
resident jitted batch step per (tenant-table pad, pow2 batch pad) shape
bucket:

    step(params, M, mu, x, tix) = h((x − mu[tix]) · M[tix])

Tenant dispatch is a take-along-tenant-index gather, so a mixed batch of
users — even from different onboarding generations — is a single fused
einsum + model forward. Every array (model params, tenant tables, request
rows, tenant indices) is a runtime ARGUMENT: executables are shared across
groups with equal padded shapes, tenant data is never baked into the
artifact (hlo_audit-enforced), and warm mixed-tenant traffic compiles
nothing (CompileCounter == 0, tested).

Admission reuses the slot-table/continuous-batching idiom of
launch/serve.py, adapted to one-shot requests: a FIFO deque is scanned for
rows of the head request's group, packed up to `max_batch`, padded to the
pow2 bucket, and served in one dispatch; oversize requests are chunked
across steps and requeue implicitly (their `served` cursor advances in
place). Statuses mirror launch/serve.py: "done" / "truncated" (partially
served when `max_steps` ran out) / "pending" (never reached a batch).
"""
from __future__ import annotations

import time
from collections import Counter, deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.federated import (PlanCache, _tree_signature, bucket_pow2,
                                  default_plan_cache)
from repro.core.protocol import FedDCLSetup
from repro.models import mlp
from repro.serve_collab.tables import TenantTable, build_tables


@dataclass
class CollabRequest:
    """One prediction request: `x` rows through tenant (group, user)."""
    rid: int
    group: int
    user: int
    x: np.ndarray                      # (n, m) float; (m,) is auto-promoted
    out: Optional[np.ndarray] = None   # (n, out_dim), filled as rows serve
    served: int = 0
    status: str = "pending"            # pending | truncated | done
    t_submit: float = field(default=0.0, repr=False)
    t_done: float = field(default=0.0, repr=False)

    def __post_init__(self):
        self.x = np.asarray(self.x, np.float32)
        if self.x.ndim == 1:
            self.x = self.x[None, :]

    @property
    def rows(self) -> int:
        return int(self.x.shape[0])

    @property
    def latency(self) -> float:
        return self.t_done - self.t_submit


class ServeOutput(Dict[int, np.ndarray]):
    """{rid: served output rows} plus `.status`: {rid: done|truncated|pending}."""

    def __init__(self, outputs: Dict[int, np.ndarray],
                 status: Dict[int, str]):
        super().__init__(outputs)
        self.status = status


def serve_step(params, M, mu, x, tix):
    """The resident batch step — a PURE function of its arguments.

    params: model pytree;  M: (T_pad, m, m̂) tenant maps;  mu: (T_pad, m)
    offsets;  x: (B_pad, m) request rows;  tix: (B_pad,) tenant indices.
    Padded rows carry tix 0 and produce garbage the server slices away.
    """
    z = x - mu[tix]                                   # (B, m)
    h = jnp.einsum("bm,bmh->bh", z, M[tix])           # (B, m̂)
    return mlp.mlp_forward(params, h)


class ServeCollab:
    """Queued, bucketed, continuously-admitted collaboration serving."""

    def __init__(self, tables: Sequence[TenantTable], params: Any, *,
                 setup: Optional[FedDCLSetup] = None,
                 max_batch: int = 256, cache: Optional[PlanCache] = None,
                 bucket=bucket_pow2):
        self.tables: List[TenantTable] = list(tables)
        self.params = params
        self.setup = setup
        self.max_batch = int(max_batch)
        self.bucket = bucket
        self.cache = cache if isinstance(cache, PlanCache) \
            else default_plan_cache()
        self.queue: deque = deque()
        self._psig = _tree_signature(params)
        self._next_rid = 0
        self.steps = 0
        self.rows_served = 0
        self.requests_done = 0
        self.latencies: List[float] = []
        self.bucket_hist: Counter = Counter()   # (group, T_pad, B_pad) -> steps

    # -- construction ------------------------------------------------------

    @classmethod
    def from_setup(cls, setup: FedDCLSetup, params: Any,
                   **kw) -> "ServeCollab":
        return cls(build_tables(setup), params, setup=setup, **kw)

    @classmethod
    def from_model(cls, model, **kw) -> "ServeCollab":
        """Bind to a fitted repro.api.FedDCL estimator."""
        if model.setup_ is None:
            raise RuntimeError("call fit() before serve()")
        return cls.from_setup(model.setup_, model.params_, **kw)

    # -- admission ---------------------------------------------------------

    def submit(self, x: np.ndarray, group: int, user: int,
               rid: Optional[int] = None) -> CollabRequest:
        """Enqueue rows for tenant (group, user); returns the request."""
        if not 0 <= group < len(self.tables):
            raise ValueError(f"unknown group {group}")
        if not 0 <= user < self.tables[group].count:
            raise ValueError(f"unknown user {user} in group {group} "
                             f"(count={self.tables[group].count})")
        if rid is None:
            rid = self._next_rid
        self._next_rid = max(self._next_rid, rid) + 1
        req = CollabRequest(rid=rid, group=group, user=user, x=x)
        req.t_submit = time.perf_counter()
        self.queue.append(req)
        return req

    # -- the resident step -------------------------------------------------

    def _step_fn(self, t_pad: int, b_pad: int, m: int, m_hat: int):
        """The compiled step for one shape bucket, through the plan cache.
        The key is ALL-shape (no group id, no tenant identity): groups with
        equal padded shapes share one executable, and warm lookups build
        nothing."""
        key = ("serve_collab", int(m), int(m_hat), int(t_pad), int(b_pad),
               self._psig)
        fn, _ = self.cache.lookup(key, lambda: jax.jit(serve_step))
        return fn

    def lower_step(self, group: int, b_pad: int):
        """Lower (don't run) the serve step for a bucket — feed for
        analysis.hlo_audit (assert_no_baked_data / collective_census)."""
        tbl = self.tables[group]
        x = jnp.zeros((b_pad, tbl.in_dim), jnp.float32)
        tix = jnp.zeros((b_pad,), jnp.int32)
        return jax.jit(serve_step).lower(self.params, tbl.M, tbl.mu, x, tix)

    # -- serving loop ------------------------------------------------------

    def step(self) -> int:
        """Serve ONE bucket: pack rows of the head request's group from the
        queue (FIFO within the group, other groups undisturbed), pad to the
        pow2 width, dispatch the resident step, scatter outputs back.
        Returns rows served (0 when idle)."""
        if not self.queue:
            return 0
        g = self.queue[0].group
        tbl = self.tables[g]
        batch: List[tuple] = []                    # (req, lo, take)
        rows = 0
        for req in self.queue:
            if req.group != g:
                continue
            take = min(req.rows - req.served, self.max_batch - rows)
            if take <= 0:
                continue
            batch.append((req, req.served, take))
            rows += take
            if rows >= self.max_batch:
                break
        b_pad = self.bucket(rows)
        x = np.zeros((b_pad, tbl.in_dim), np.float32)
        tix = np.zeros((b_pad,), np.int32)
        at = 0
        for req, lo, take in batch:
            x[at:at + take] = req.x[lo:lo + take]
            tix[at:at + take] = req.user
            at += take
        fn = self._step_fn(tbl.t_pad, b_pad, tbl.in_dim, tbl.out_dim)
        y = np.asarray(fn(self.params, tbl.M, tbl.mu, x, tix))
        at = 0
        now = time.perf_counter()
        for req, lo, take in batch:
            if req.out is None:
                req.out = np.zeros((req.rows, y.shape[1]), np.float32)
            req.out[lo:lo + take] = y[at:at + take]
            at += take
            req.served += take
            req.status = "truncated"               # partially served so far
            if req.served == req.rows:
                req.status = "done"
                req.t_done = now
                self.latencies.append(req.latency)
                self.requests_done += 1
        self.queue = deque(r for r in self.queue if r.served < r.rows)
        self.steps += 1
        self.rows_served += rows
        self.bucket_hist[(g, tbl.t_pad, b_pad)] += 1
        return rows

    def serve(self, requests: Optional[Sequence[CollabRequest]] = None, *,
              max_steps: int = 10_000) -> ServeOutput:
        """Drain the queue (plus `requests`, submitted first) through at
        most `max_steps` dispatches. The returned mapping holds each
        request's SERVED rows; `.status` distinguishes finished requests
        from ones truncated mid-serve or never admitted."""
        tracked: List[CollabRequest] = list(self.queue)
        for req in requests or ():
            req.t_submit = time.perf_counter()
            self.queue.append(req)
            tracked.append(req)
        steps = 0
        while self.queue and steps < max_steps:
            self.step()
            steps += 1
        outputs = {r.rid: (r.out[: r.served] if r.out is not None
                           else np.zeros((0, 0), np.float32))
                   for r in tracked}
        return ServeOutput(outputs, {r.rid: r.status for r in tracked})

    # -- live onboarding ---------------------------------------------------

    def _refresh_tables(self) -> None:
        """Rebuild every group's table from the (refreshed) setup: Z moved,
        so every tenant's combined map changed — table CONTENT is runtime
        data, only a grown pow2 tenant pad can introduce a new bucket."""
        self.tables = build_tables(self.setup, self.bucket)

    def onboard_user(self, i: int, X_new: np.ndarray,
                     Y_new: np.ndarray) -> int:
        """Onboard a new user into group i of the LIVE server (incremental
        protocol update, DESIGN.md §10) and refresh the tenant tables; the
        queue and compiled buckets stay warm. Returns the new user index."""
        if self.setup is None:
            raise RuntimeError(
                "this server was built from raw tables; onboarding needs "
                "ServeCollab.from_setup/from_model (a FedDCLSetup with "
                "onboarding state)")
        j = self.setup.onboard_user(i, X_new, Y_new)
        self._refresh_tables()
        return j

    def onboard_silo(self, Xs_new: Sequence[np.ndarray],
                     Ys_new: Sequence[np.ndarray]) -> int:
        """Onboard a whole new group onto the live server; returns its
        index (immediately servable)."""
        if self.setup is None:
            raise RuntimeError(
                "this server was built from raw tables; onboarding needs "
                "ServeCollab.from_setup/from_model")
        i = self.setup.onboard_silo(Xs_new, Ys_new)
        self._refresh_tables()
        return i

    # -- introspection -----------------------------------------------------

    def stats(self) -> Dict[str, Any]:
        lat = sorted(self.latencies)

        def pct(p: float) -> float:
            if not lat:
                return 0.0
            return lat[min(len(lat) - 1, int(p * len(lat)))]

        return {
            "steps": self.steps,
            "rows_served": self.rows_served,
            "requests_done": self.requests_done,
            "queued": len(self.queue),
            "p50_latency_s": pct(0.50),
            "p99_latency_s": pct(0.99),
            "buckets": {f"g{g}/T{t}/B{b}": n
                        for (g, t, b), n in sorted(self.bucket_hist.items())},
            "cache": self.cache.stats(),
        }
