"""Partition a dataset across d groups × c_i users (the paper's layout),
IID or non-IID (Dirichlet label skew / feature-cluster skew)."""
from __future__ import annotations

from typing import List, Sequence, Tuple

import numpy as np


def split_iid(X: np.ndarray, Y: np.ndarray, d: int, c: Sequence[int],
              n_ij: int, seed: int = 0):
    """-> (Xs[i][j], Ys[i][j]) with n_ij samples per user, IID."""
    rng = np.random.default_rng(seed)
    total = n_ij * int(np.sum(c))
    assert total <= X.shape[0], f"need {total} samples, have {X.shape[0]}"
    perm = rng.permutation(X.shape[0])[:total]
    Xs, Ys, k = [], [], 0
    for i in range(d):
        gx, gy = [], []
        for _ in range(c[i]):
            sl = perm[k * n_ij : (k + 1) * n_ij]
            gx.append(X[sl])
            gy.append(Y[sl])
            k += 1
        Xs.append(gx)
        Ys.append(gy)
    return Xs, Ys


def split_dirichlet(X: np.ndarray, Y: np.ndarray, d: int, c: Sequence[int],
                    n_ij: int, alpha: float = 0.5, seed: int = 0):
    """Non-IID label-skew partition: each user's class mix ~ Dir(alpha).
    Regression targets are bucketed into quintiles first."""
    rng = np.random.default_rng(seed)
    y = Y if Y.ndim == 1 else np.digitize(
        Y[:, 0], np.quantile(Y[:, 0], [0.2, 0.4, 0.6, 0.8]))
    classes = np.unique(y)
    by_class = {cl: list(rng.permutation(np.where(y == cl)[0])) for cl in classes}
    Xs, Ys = [], []
    for i in range(d):
        gx, gy = [], []
        for _ in range(c[i]):
            p = rng.dirichlet(alpha * np.ones(len(classes)))
            idx: List[int] = []
            want = rng.multinomial(n_ij, p)
            for cl, w in zip(classes, want):
                take = by_class[cl][:w]
                by_class[cl] = by_class[cl][w:]
                idx.extend(take)
            # backfill if a class ran dry
            while len(idx) < n_ij:
                for cl in classes:
                    if by_class[cl]:
                        idx.append(by_class[cl].pop())
                        if len(idx) == n_ij:
                            break
            sl = np.asarray(idx[:n_ij])
            gx.append(X[sl])
            gy.append(Y[sl])
        Xs.append(gx)
        Ys.append(gy)
    return Xs, Ys
