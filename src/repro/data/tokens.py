"""Synthetic token pipeline for LM training (the end-to-end driver and the
federated LLM examples). Deterministic, seekable, silo-aware.

Generator: a hidden affine-recurrence language over an effective vocabulary
V_eff ≤ vocab: t_{k+1} = (a·t_k + b) mod V_eff with segment restarts and
per-silo (a, b) flavour under non-IID mode — learnable structure so training
loss demonstrably falls, with controllable cross-silo heterogeneity (the
paper's non-IID axis)."""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np


@dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    v_eff: int = 2048
    segment: int = 64
    silo: int = 0
    non_iid: bool = False

    def __post_init__(self):
        self.v_eff = min(self.v_eff, self.vocab_size)
        rng = np.random.default_rng(self.seed + (self.silo if self.non_iid else 0))
        # odd multiplier -> full-period affine map mod 2^k-ish vocab
        self._a = int(rng.integers(1, self.v_eff // 2)) * 2 + 1
        self._b = int(rng.integers(0, self.v_eff))

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            (self.seed * 1_000_003 + step * 7919 + self.silo * 104729) % (2**63))
        B, S = self.batch_size, self.seq_len
        starts = rng.integers(0, self.v_eff, size=(B, (S + self.segment) // self.segment + 1))
        toks = np.empty((B, S + 1), np.int64)
        for b in range(B):
            seq = []
            si = 0
            while len(seq) < S + 1:
                t = int(starts[b, si])
                si += 1
                for _ in range(self.segment):
                    seq.append(t)
                    t = (self._a * t + self._b) % self.v_eff
            toks[b] = np.asarray(seq[: S + 1])
        # sprinkle noise tokens (makes the task non-trivial)
        mask = rng.random((B, S + 1)) < 0.02
        toks[mask] = rng.integers(0, self.vocab_size, size=int(mask.sum()))
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }


def silo_batches(vocab_size: int, seq_len: int, per_silo_batch: int,
                 num_silos: int, step: int, *, seed: int = 0,
                 non_iid: bool = False) -> Dict[str, np.ndarray]:
    """Stacked per-silo batches with a leading silo dim: tokens
    (d, b, S) — feeds the silo-vmapped federated train step."""
    outs = [
        TokenStream(vocab_size, seq_len, per_silo_batch, seed=seed, silo=s,
                    non_iid=non_iid).batch(step)
        for s in range(num_silos)
    ]
    return {k: np.stack([o[k] for o in outs]) for k in outs[0]}
