"""Synthetic stand-ins for the paper's six tabular datasets (§4.3, Table 3).

The real datasets are MATLAB-toolbox / credentialed / network-gated (see
DESIGN.md §2 — data gate of the repro band). Each stand-in matches the
original's (n, m, task, #classes) and its qualitative structure:

  * an approximately low-rank latent factor structure (so PCA-based
    intermediate representations retain signal — the regime the DC family
    of methods targets and the paper's experiments exercise), plus
  * a target that is a (mildly nonlinear) function of the latents, plus
  * heteroscedastic noise and feature-range diversity.

The paper's claims we validate are RELATIVE (FedDCL ≈ FedAvg ≈ DC ≫ Local;
FedDCL faster per-round than FedAvg), which transfer to any dataset with
this structure; absolute RMSE/accuracy digits do not (documented in
EXPERIMENTS.md).
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Dict, Tuple

import numpy as np

from repro.configs.feddcl_mlp import PAPER_MLPS, MLPConfig


@dataclass
class Dataset:
    name: str
    X: np.ndarray           # (n, m) float64
    Y: np.ndarray           # (n, out) float64 (regression) | (n,) int (classif.)
    task: str
    cfg: MLPConfig


def _latent_regression(rng, n: int, m: int, latent: int, *, noise: float,
                       nonlinearity: float = 0.3):
    """X = s(Z) @ W + eps; y = g(Z). Low-rank X with target tied to latents."""
    Z = rng.standard_normal((n, latent))
    W = rng.standard_normal((latent, m)) / np.sqrt(latent)
    X = Z @ W + noise * rng.standard_normal((n, m))
    w_y = rng.standard_normal((latent,)) / np.sqrt(latent)
    y = Z @ w_y + nonlinearity * np.tanh(Z[:, 0] * Z[:, min(1, latent - 1)])
    y = (y - y.mean()) / (y.std() + 1e-9)
    # per-feature affine ranges (like physical sensor units)
    scale = rng.uniform(0.5, 3.0, size=m)
    shift = rng.uniform(-1.0, 1.0, size=m)
    X = X * scale[None, :] + shift[None, :]
    return X, y[:, None]


def _latent_classification(rng, n: int, m: int, latent: int, classes: int, *,
                           noise: float, sep: float = 2.2):
    """Class-conditional latent Gaussians -> low-rank features."""
    y = rng.integers(0, classes, size=n)
    # scale of the raw draw is irrelevant: the next line projects centers
    # onto the radius-`sep` sphere (a dead `* sep / sqrt(l) * sqrt(l)`
    # factor used to sit here; removing it keeps the RNG draw sequence
    # identical and perturbs centers only in the last ulp of the division)
    centers = rng.standard_normal((classes, latent))
    centers = centers / np.linalg.norm(centers, axis=1, keepdims=True) * sep
    Z = centers[y] + rng.standard_normal((n, latent))
    W = rng.standard_normal((latent, m)) / np.sqrt(latent)
    X = Z @ W + noise * rng.standard_normal((n, m))
    return X, y.astype(np.int64)


_SPECS: Dict[str, Dict] = {
    # name: latent dim, noise, classes (None = regression)
    "battery_small": dict(latent=3, noise=0.15, classes=None),
    "credit_rating": dict(latent=6, noise=0.25, classes=None),
    "eicu": dict(latent=8, noise=0.40, classes=None),
    "human_activity": dict(latent=12, noise=0.35, classes=5),
    "mnist": dict(latent=30, noise=0.30, classes=10),
    "fashion_mnist": dict(latent=30, noise=0.45, classes=10),
}


def make_dataset(name: str, n: int, seed: int = 0) -> Dataset:
    cfg = PAPER_MLPS[name]
    spec = _SPECS[name]
    # zlib.crc32, not hash(): str hashing is salted per process
    # (PYTHONHASHSEED), which made every process draw a different dataset
    rng = np.random.default_rng(seed ^ zlib.crc32(name.encode()) % (2**31))
    if spec["classes"] is None:
        X, Y = _latent_regression(rng, n, cfg.in_dim, spec["latent"],
                                  noise=spec["noise"])
        task = "regression"
    else:
        X, Y = _latent_classification(rng, n, cfg.in_dim, spec["latent"],
                                      spec["classes"], noise=spec["noise"])
        task = "classification"
    return Dataset(name=name, X=X, Y=Y, task=task, cfg=cfg)


def train_test_split(ds: Dataset, n_train: int, n_test: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    assert n_train + n_test <= ds.X.shape[0]
    perm = rng.permutation(ds.X.shape[0])
    tr, te = perm[:n_train], perm[n_train : n_train + n_test]
    return (ds.X[tr], ds.Y[tr]), (ds.X[te], ds.Y[te])
