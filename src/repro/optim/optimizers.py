"""Minimal functional optimizer library (optax is not installed offline).

API mirrors optax: ``opt.init(params) -> state``, ``opt.update(grads, state,
params) -> (updates, state)``; updates are ADDED to params by
``apply_updates``. All states are pytrees, so they vmap over a leading silo
dim (federated training) and shard like parameters.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[..., Any]


def apply_updates(params, updates):
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree_util.tree_leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def sgd(lr, momentum: float = 0.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        state = {"step": jnp.zeros((), jnp.int32)}
        if momentum:
            state["mu"] = jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params)
        return state

    def update(grads, state, params=None):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        if momentum:
            mu = jax.tree.map(lambda m, g: momentum * m + g.astype(jnp.float32),
                              state["mu"], grads)
            upd = jax.tree.map(lambda m: -lr_t * m, mu)
            return upd, {"step": step, "mu": mu}
        upd = jax.tree.map(lambda g: -lr_t * g.astype(jnp.float32), grads)
        return upd, {"step": step}

    return Optimizer(init, update)


def adamw(lr, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.0, state_dtype=jnp.float32) -> Optimizer:
    """AdamW with decoupled weight decay. ``state_dtype=bf16`` halves
    optimizer memory for very large models (deepseek-v3 multi-pod fit)."""
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, state_dtype)
        return {
            "step": jnp.zeros((), jnp.int32),
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        lr_t = lr_fn(step)
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd_m(m, g):
            return (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(state_dtype)

        def upd_v(v, g):
            gf = g.astype(jnp.float32)
            return (b2 * v.astype(jnp.float32) + (1 - b2) * gf * gf).astype(state_dtype)

        m = jax.tree.map(upd_m, state["m"], grads)
        v = jax.tree.map(upd_v, state["v"], grads)

        def u(m_, v_, p):
            mhat = m_.astype(jnp.float32) / bc1
            vhat = v_.astype(jnp.float32) / bc2
            step_ = mhat / (jnp.sqrt(vhat) + eps)
            if weight_decay:
                step_ = step_ + weight_decay * p.astype(jnp.float32)
            return -lr_t * step_

        updates = jax.tree.map(u, m, v, params)
        return updates, {"step": step, "m": m, "v": v}

    return Optimizer(init, update)
