"""Pallas TPU kernel for the chunked WKV6 recurrence.

Grid: (B*H, S/L) — the chunk axis is sequential on TPU, so the recurrent
state lives in a VMEM scratch buffer that persists across chunk steps for a
fixed (batch, head) program. Within a chunk the pairwise decay is factored
into two (L, K) operands and hits the MXU as an (L,K)@(K,L) matmul.

VMEM budget per program (L=16, K=V=64, fp32):
  r,k,v,lw blocks: 4 × L×K×4   =  16 KiB
  state scratch:   K×V×4       =  16 KiB
  A matrix:        L×L×4       =   1 KiB
comfortably inside the ~16 MiB VMEM of a TPU core; block shapes are padded
to the fp32 (8, 128) tile by Pallas.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, state_ref):
    chunk_idx = pl.program_id(1)

    @pl.when(chunk_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    r = r_ref[0].astype(jnp.float32)          # (L, K)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)          # (L, V)
    lw = lw_ref[0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)          # (K,)

    L = r.shape[0]
    c = jnp.cumsum(lw, axis=0)                # inclusive log-decay
    cs = c - lw                               # exclusive
    r_t = r * jnp.exp(cs)
    k_t = k * jnp.exp(-c)

    A = jax.lax.dot_general(
        r_t, k_t, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )                                         # (L, L)
    idx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jdx = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    A = jnp.where(idx > jdx, A, 0.0)
    diag = jnp.sum(r * k * u[None, :], axis=-1)          # (L,)

    state = state_ref[...]                    # (K, V)
    y = (
        jax.lax.dot_general(A, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
        + diag[:, None] * v
        + jax.lax.dot_general(r_t, state, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)
    )
    o_ref[0] = y.astype(o_ref.dtype)

    k_end = k * jnp.exp(c[-1:, :] - c)
    state_ref[...] = state * jnp.exp(c[-1, :])[:, None] + jax.lax.dot_general(
        k_end, v, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv6_pallas(r, k, v, log_w, u, *, chunk: int = 16, interpret: bool = False):
    """r/k/log_w: (BH, S, K); v: (BH, S, V); u: (BH, K). -> fp32 (BH, S, V)."""
    BH, S, K = r.shape
    V = v.shape[-1]
    L = min(chunk, S)
    assert S % L == 0, f"seq {S} % chunk {L} != 0"
    grid = (BH, S // L)

    seq_spec = pl.BlockSpec((1, L, K), lambda g, c: (g, c, 0))
    val_spec = pl.BlockSpec((1, L, V), lambda g, c: (g, c, 0))
    u_spec = pl.BlockSpec((1, K), lambda g, c: (g, 0))

    return pl.pallas_call(
        _wkv6_kernel,
        grid=grid,
        in_specs=[seq_spec, seq_spec, val_spec, seq_spec, u_spec],
        out_specs=val_spec,
        out_shape=jax.ShapeDtypeStruct((BH, S, V), jnp.float32),
        # persistent recurrent state across the sequential chunk axis
        scratch_shapes=[pltpu.VMEM((K, V), jnp.float32)],
        interpret=interpret,
    )(r, k, v, log_w, u)
