"""jit'd public wrapper for WKV6: model-layout in/out, backend dispatch.

On CPU (this container) the Pallas TPU kernel is executed in interpret mode
for tests and the chunked jnp form is used for real training; on TPU the
Pallas kernel is the default.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.rwkv6 import ref
from repro.kernels.rwkv6.kernel import wkv6_pallas


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


@functools.partial(jax.jit, static_argnames=("chunk", "backend"))
def wkv6(r, k, v, log_w, u, *, chunk: int = 16, backend: str = "auto"):
    """r/k/log_w: (B, S, H, K); v: (B, S, H, V); u: (H, K) -> (B, S, H, V) fp32.

    backend: auto | pallas | interpret | chunked | scan
    """
    B, S, H, K = r.shape
    V = v.shape[-1]
    if backend == "auto":
        backend = "pallas" if _on_tpu() else "chunked"
    if backend == "scan":
        return ref.wkv6_scan(r, k, v, log_w, u)
    if backend == "chunked":
        return ref.wkv6_chunked(r, k, v, log_w, u, chunk=chunk)

    def fold(t, last):
        return t.astype(jnp.float32).transpose(0, 2, 1, 3).reshape(B * H, S, last)

    rk = fold(r, K)
    kk = fold(k, K)
    vk = fold(v, V)
    lw = fold(log_w, K)
    uu = jnp.tile(u.astype(jnp.float32), (B, 1))
    out = wkv6_pallas(rk, kk, vk, lw, uu, chunk=chunk,
                      interpret=(backend == "interpret"))
    return out.reshape(B, H, S, V).transpose(0, 2, 1, 3)
