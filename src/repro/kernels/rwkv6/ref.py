"""Pure-jnp oracles for the WKV6 recurrence (RWKV-6 "Finch" time mix).

Recurrence (per batch, head; K = key dim, V = value dim):

    o_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,     w_t = exp(log_w_t) in (0, 1)

``wkv6_scan`` is the exact sequential oracle. ``wkv6_chunked`` is the
MXU-friendly chunked form used for training; within a chunk it factors the
pairwise decay exp(cs_t - c_i) into (r ⊙ e^{cs}) @ (k ⊙ e^{-c})^T.

Stability note: e^{-c_i} grows with per-step decay × chunk length. The model
clips log_w >= -e^{1.6} ~= -4.95 and we use chunk <= 16, bounding |c| <= 79.2
so every intermediate stays inside fp32 range (max ~3.4e38; worst-case
masked upper-triangle partials sum to ~1e37). The chunked result is EXACT
(the factoring is algebra, not approximation) within that domain.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

MAX_CHUNK = 16


def wkv6_scan(r, k, v, log_w, u):
    """Exact oracle. r/k/log_w: (B, S, H, K); v: (B, S, H, V); u: (H, K).
    Returns fp32 (B, S, H, V)."""
    B, S, H, K = r.shape
    V = v.shape[-1]
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    lw = log_w.astype(jnp.float32)
    uf = u.astype(jnp.float32)

    def step(state, inp):
        rt, kt, vt, lwt = inp                                   # (B,H,K/V)
        kv = jnp.einsum("bhk,bhv->bhkv", kt, vt)
        o = jnp.einsum("bhk,bhkv->bhv", rt, state + uf[None, :, :, None] * kv)
        new = jnp.exp(lwt)[..., None] * state + kv
        return new, o

    init = jnp.zeros((B, H, K, V), jnp.float32)
    xs = (rf.swapaxes(0, 1), kf.swapaxes(0, 1), vf.swapaxes(0, 1), lw.swapaxes(0, 1))
    _, os = lax.scan(step, init, xs)
    return os.swapaxes(0, 1)                                    # (B,S,H,V)


def wkv6_chunked(r, k, v, log_w, u, *, chunk: int = 16,
                 return_state: bool = False, shard: str = "k"):
    """Chunked exact WKV6. Same shapes as wkv6_scan; fp32 output.
    With return_state, also returns the final recurrent state (B, H, K, V).

    shard: mesh placement of the folded chunk tensors —
      "k"   (baseline) key dim on the model axis: intra-chunk matmuls
            contract a sharded dim, all-reducing every (L, L) A matrix;
      "seq" chunk dim on the model axis (sequence parallelism): intra-chunk
            work is embarrassingly parallel, only the log-depth inter-chunk
            pscan communicates. The §Perf hillclimb for rwkv6 train_4k."""
    B, S0, H, K = r.shape
    V = v.shape[-1]
    L = min(chunk, MAX_CHUNK, S0)
    pad = (-S0) % L
    if pad:
        # zero r/k/v with log_w = 0 (w = 1): outputs at padded positions are
        # discarded and the recurrent state passes through unchanged.
        zpad = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v, log_w = zpad(r), zpad(k), zpad(v), zpad(log_w)
    S = S0 + pad
    nc = S // L

    def fold(t, last):
        # (B,S,H,X) -> (B*H, nc, L, X)
        return (
            t.astype(jnp.float32)
            .reshape(B, nc, L, H, last)
            .transpose(0, 3, 1, 2, 4)
            .reshape(B * H, nc, L, last)
        )

    rf, kf, lw = fold(r, K), fold(k, K), fold(log_w, K)
    vf = fold(v, V)
    uf = jnp.tile(u.astype(jnp.float32), (B, 1))                # (B*H, K)
    from repro.shardingx.constrain import constrain
    model_dim = 1 if shard == "seq" else 3      # nc-dim vs K-dim placement
    spec = [("batch" if i == 0 else ("model" if i == model_dim else None))
            for i in range(4)]
    rf = constrain(rf, *spec)
    kf = constrain(kf, *spec)
    lw = constrain(lw, *spec)
    vf = constrain(vf, *spec)

    c = jnp.cumsum(lw, axis=2)                                  # inclusive
    cs = c - lw                                                 # exclusive (c_{t-1})
    r_t = rf * jnp.exp(cs)
    k_t = kf * jnp.exp(-c)

    A = jnp.einsum("gntk,gnik->gnti", r_t, k_t)                 # (BH,nc,L,L)
    idx = jnp.arange(L)
    strict = idx[:, None] > idx[None, :]
    A = jnp.where(strict[None, None], A, 0.0)
    diag = jnp.einsum("gntk,gk->gnt", rf * kf, uf)
    y_intra = jnp.einsum("gnti,gniv->gntv", A, vf) + diag[..., None] * vf

    # chunk-final state contribution: sum_i (k_i e^{c_L - c_i}) v_i^T
    k_end = kf * jnp.exp(c[:, :, -1:, :] - c)
    contrib = jnp.einsum("gnik,gniv->gnkv", k_end, vf)          # (BH,nc,K,V)
    chunk_decay = jnp.exp(c[:, :, -1, :])                       # (BH,nc,K)

    # inter-chunk recurrence via associative scan (log depth, TPU-parallel)
    from repro.models.layers import _prev_states
    prev, final_state = _prev_states(chunk_decay, contrib, extra_dims=1)
    y_inter = jnp.einsum("gntk,gnkv->gntv", r_t, prev)

    y = y_intra + y_inter                                       # (BH,nc,L,V)
    out = y.reshape(B, H, nc, L, V).transpose(0, 2, 3, 1, 4).reshape(B, S, H, V)
    out = out[:, :S0]
    if return_state:
        # padded tail contributes k=0 kv outer products with unit decay, so
        # the "final" state equals the state after the true last token ONLY
        # if we also fold the last partial chunk; scan_body emitted states
        # BEFORE each chunk, so recompute: state after S0 = decay/contrib of
        # the final (padded) chunk applied to its entry state — padding makes
        # that exactly the state at S0.
        return out, final_state.reshape(B, H, K, V)
    return out
