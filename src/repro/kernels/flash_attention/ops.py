"""Public fused-attention entry point, model layout (B, S, H, hd)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.kernel import flash_attention_pallas


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "backend", "q_offset"),
)
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, q_offset: int = 0,
                    backend: str = "auto"):
    """q: (B, Sq, H, hd); k/v: (B, Sk, KV, hd) -> (B, Sq, H, hd)."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    qh = q.swapaxes(1, 2)
    kh = k.swapaxes(1, 2)
    vh = v.swapaxes(1, 2)
    if backend == "ref":
        out = ref.mha_reference(qh, kh, vh, causal=causal, window=window,
                                softcap=softcap, q_offset=q_offset)
    else:
        out = flash_attention_pallas(
            qh, kh, vh, causal=causal, window=window, softcap=softcap,
            q_offset=q_offset, interpret=(backend == "interpret"),
        )
    return out.swapaxes(1, 2)
