"""Pallas TPU flash attention: online-softmax, GQA head mapping, causal /
sliding-window masking and Gemma-2 logit softcapping fused in-kernel.

Grid: (B, H, Sq/BQ, Sk/BK) — the key axis is innermost/sequential; running
max / normalizer / accumulator live in VMEM scratch. Fully-masked key blocks
(beyond the causal frontier or the sliding window) are skipped with pl.when,
so the compute volume matches the mask, not the dense Sq×Sk rectangle.

VMEM per program at BQ=BK=512, hd=128, fp32 scratch:
  q,k,v blocks:  3 × 512×128×4 = 768 KiB   (bf16 inputs: 384 KiB)
  logits:        512×512×4     =   1 MiB
  acc + m + l:   512×128×4 + 2×512×128×4 ≈ 768 KiB
≈ 2.5 MiB — well under the 16 MiB/core VMEM budget; MXU dims (512, 128)
are multiples of the 128×128 systolic tile.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
LANES = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  sm_scale: float, causal: bool, window: int, softcap: float,
                  block_q: int, block_k: int, seq_k: int, q_offset: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q + q_offset
    k_start = ki * block_k

    # block-level skip: never any (q, k) pair with k visible
    live = True
    if causal:
        live = k_start <= q_start + block_q - 1
    if window and window > 0:
        live = jnp.logical_and(live, k_start + block_k - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (BQ, hd)
        k = k_ref[0, 0].astype(jnp.float32)            # (BK, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        logits = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                                    # (BQ, BK)
        if softcap and softcap > 0:
            logits = jnp.tanh(logits / softcap) * softcap
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, logits.shape, 1)
        mask = kpos < seq_k
        if causal:
            mask &= kpos <= qpos
        if window and window > 0:
            mask &= kpos > qpos - window
        logits = jnp.where(mask, logits, NEG_INF)

        m_prev = m_scr[:, 0]
        l_prev = l_scr[:, 0]
        m_cur = jnp.max(logits, axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_scr[...] = jnp.broadcast_to(m_new[:, None], m_scr.shape)
        l_scr[...] = jnp.broadcast_to(l_new[:, None], l_scr.shape)

    @pl.when(ki == nk - 1)
    def _finish():
        l = l_scr[:, 0]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0, 0] = (acc_scr[...] / safe[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "softcap", "block_q", "block_k",
                     "q_offset", "interpret"),
)
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           softcap: float = 0.0, block_q: int = 512,
                           block_k: int = 512, q_offset: int = 0,
                           interpret: bool = False):
    """q: (B, H, Sq, hd); k/v: (B, KV, Sk, hd). Returns (B, H, Sq, hd)."""
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    assert Sq % bq == 0 and Sk % bk == 0
    grid = (B, H, Sq // bq, Sk // bk)

    kernel = functools.partial(
        _flash_kernel,
        sm_scale=1.0 / math.sqrt(hd),
        causal=causal, window=window, softcap=softcap,
        block_q=bq, block_k=bk, seq_k=Sk, q_offset=q_offset,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd), lambda b, h, qi, ki: (b, h // G, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd), lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, LANES), jnp.float32),   # running max
            pltpu.VMEM((bq, LANES), jnp.float32),   # running normalizer
            pltpu.VMEM((bq, hd), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
