"""Pure-jnp oracle for fused attention (GQA + causal + sliding window +
logit softcap). Layout: q (B, H, Sq, hd); k/v (B, KV, Sk, hd)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def mha_reference(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0, q_offset: int = 0) -> jnp.ndarray:
    B, H, Sq, hd = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    G = H // KV
    qg = q.reshape(B, KV, G, Sq, hd)
    logits = jnp.einsum("bhgqk,bhsk->bhgqs", qg, k).astype(jnp.float32)
    logits = logits / math.sqrt(hd)
    if softcap and softcap > 0:
        logits = jnp.tanh(logits / softcap) * softcap
    qpos = jnp.arange(Sq) + q_offset
    kpos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window and window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    logits = jnp.where(mask[None, None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bhgqs,bhsk->bhgqk", probs, v)
    return ctx.reshape(B, H, Sq, hd)
