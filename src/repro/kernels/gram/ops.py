"""Public Gram-reduction wrapper with backend dispatch."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gram import ref
from repro.kernels.gram.kernel import gram_pallas


@functools.partial(jax.jit, static_argnames=("backend",))
def gram(a, *, backend: str = "auto"):
    """a: (r, m) -> A^T A in fp32."""
    if backend == "auto":
        backend = "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend == "ref":
        return ref.gram_reference(a)
    return gram_pallas(a, interpret=(backend == "interpret"))


def gram_eigh_topk(a, k: int, *, backend: str = "auto"):
    """Rank-k left singular pairs of a (r, m) via the Gram route:
    eigh(AᵀA) -> right vectors V, singular values s; U = A V / s.

    Returns (U (r,k), s (k,), V (m,k)). Matches jnp.linalg.svd up to sign
    for well-separated spectra (tested).
    """
    g = gram(a, backend=backend)
    evals, evecs = jnp.linalg.eigh(g)                 # ascending
    evals = evals[::-1][:k]
    V = evecs[:, ::-1][:, :k]
    s = jnp.sqrt(jnp.maximum(evals, 0.0))
    U = (a.astype(jnp.float32) @ V) / jnp.maximum(s, 1e-12)[None, :]
    return U, s, V
