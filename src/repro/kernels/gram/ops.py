"""Public Gram-reduction wrappers with backend dispatch.

Single-matrix entry points (`gram`, `gram_eigh_topk`) serve the legacy
one-group-at-a-time path; the batched entry points (`gram_batched`,
`gram_eigh_topk_batched`, `solve_G_batched`) are the device-resident
collaboration engine: every group (or every user) is a slice of one stacked,
zero-padded array and the whole of FedDCL step 3 runs in a handful of jitted
calls instead of Python loops.

Padded-ragged convention (see DESIGN.md): ragged stacks are zero-padded on
the trailing column axis up to the max width. Zero columns are harmless for
the Gram route — AᵀA acquires zero rows/cols, eigh keeps them in the null
space, and the top-k eigenpairs of the real block are untouched. For least
squares they are handled explicitly via `col_mask` (see `solve_G_batched`).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.gram import ref
from repro.kernels.gram.kernel import gram_batched_pallas, gram_pallas


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    return backend


@functools.partial(jax.jit, static_argnames=("backend",))
def gram(a, *, backend: str = "auto"):
    """a: (r, m) -> A^T A in fp32."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.gram_reference(a)
    return gram_pallas(a, interpret=(backend == "interpret"))


@functools.partial(jax.jit, static_argnames=("backend",))
def gram_batched(a, *, backend: str = "auto"):
    """a: (B, r, m) -> stacked A_b^T A_b (B, m, m) fp32 in ONE dispatch."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.gram_batched_reference(a)
    return gram_batched_pallas(a, interpret=(backend == "interpret"))


def gram_eigh_topk(a, k: int, *, backend: str = "auto"):
    """Rank-k left singular pairs of a (r, m) via the Gram route:
    eigh(AᵀA) -> right vectors V, singular values s; U = A V / s.

    Returns (U (r,k), s (k,), V (m,k)) — the B=1 case of the batched
    recovery. Matches jnp.linalg.svd up to sign for well-separated
    spectra (tested).
    """
    U, s, V = gram_eigh_topk_batched(a[None], k, backend=backend)
    return U[0], s[0], V[0]


@functools.partial(jax.jit, static_argnames=("k", "backend"))
def gram_eigh_topk_batched(a, k: int, *, backend: str = "auto"):
    """Batched rank-k singular recovery: a (B, r, m) -> (U (B,r,k),
    s (B,k), V (B,m,k)) — one batched Gram reduction + one batched eigh.

    Zero-padded columns contribute zero eigenvalues and never reach the
    top-k slots as long as k ≤ rank of the real block.
    """
    g = gram_batched(a, backend=backend)              # (B, m, m)
    return eigh_topk_recover_batched(g, a, k)


@functools.partial(jax.jit, static_argnames=("k",))
def eigh_topk_recover_batched(g, a, k: int):
    """Rank-k singular recovery from a PRECOMPUTED Gram stack: the shared
    tail of `gram_eigh_topk_batched` and the incremental-onboarding path,
    where g was maintained by `gram_append_blocked` instead of being
    reduced from scratch.

    g: (B, m, m) Gram stack AᵀA;  a: (B, r, m) the matrices themselves
    (needed to recover the left factors U = A V / s).
    """
    evals, evecs = jnp.linalg.eigh(g)                 # ascending, batched
    evals = evals[:, ::-1][:, :k]
    V = evecs[:, :, ::-1][:, :, :k]                   # (B, m, k)
    s = jnp.sqrt(jnp.maximum(evals, 0.0))             # (B, k)
    U = jnp.einsum("brm,bmk->brk", a.astype(jnp.float32), V)
    U = U / jnp.maximum(s, 1e-12)[:, None, :]
    return U, s, V


@jax.jit
def gram_append_blocked(g, a_old, a_new):
    """Blocked incremental Gram update for tenant onboarding: given the
    maintained Gram g = A_oldᵀA_old and the w new columns a_new joining the
    stack, return Gram([A_old A_new]) computing ONLY the cross and new
    blocks —

        [[ g          A_oldᵀA_new ]
         [ (·)ᵀ       A_newᵀA_new ]]

    O(r·W·w) work instead of the O(r·(W+w)²) full reduction, batched over
    a leading group axis.

    g: (B, W, W);  a_old: (B, r, W);  a_new: (B, r, w) -> (B, W+w, W+w).
    """
    a_old = a_old.astype(jnp.float32)
    a_new = a_new.astype(jnp.float32)
    cross = jnp.einsum("brw,brv->bwv", a_old, a_new)      # (B, W, w)
    new = jnp.einsum("brv,bru->bvu", a_new, a_new)        # (B, w, w)
    top = jnp.concatenate([g.astype(jnp.float32), cross], axis=2)
    bot = jnp.concatenate([jnp.swapaxes(cross, 1, 2), new], axis=2)
    return jnp.concatenate([top, bot], axis=1)


@jax.jit
def apply_G_batched(x, g):
    """Batched per-user collaboration representations X̂_j = X̃_j G_j for a
    whole stack of users in ONE device matmul.

    x: (U, n_max, m̃_max) intermediate representations, zero-padded on both
       the sample axis (ragged n_j) and the column axis (ragged m̃_j)
    g: (U, m̃_max, m̂) per-user G, zero-padded on the row axis

    Padded columns of x only ever meet zero rows of g, so the real block of
    the product is EXACT; padded sample rows produce garbage that callers
    slice away. No masks needed.
    """
    return jnp.einsum("unm,umh->unh", x.astype(jnp.float32),
                      g.astype(jnp.float32))


@jax.jit
def solve_G_batched(a, z, col_mask=None, ridge: float = 0.0):
    """Batched eq. (3): G_b = argmin ‖A_b G − Z_b‖_F for a whole stack of
    users in one jitted QR solve.

    a:        (B, r, m_max) anchors, zero-padded on the column axis
    z:        (r, m_hat) shared target, or (B, r, m_hat) per-batch targets
    col_mask: (B, m_max) with True on REAL columns (None = all real)
    ridge:    relative Tikhonov strength (see below); 0.0 = exact lstsq

    Returns G (B, m_max, m_hat) with exact zero rows at padded positions.

    Padded columns would make the QR factor singular, so the system is
    augmented with m_max extra rows holding diag(1 − mask): the objective
    becomes ‖A_real G_real − Z‖² + Σ_padded G_k², whose minimiser is the
    plain least-squares solution on real columns and 0 on padded rows
    (cross terms vanish because padded columns of A are exactly zero).
    Unlike normal equations this does not square the condition number.

    QR without pivoting requires the REAL columns to be full rank — the
    protocol guarantees this generically (anchors are random full-rank
    matrices through injective maps), but exactly collinear anchor columns
    would blow the triangular solve up where host lstsq returns the bounded
    min-norm solution. For such degenerate inputs pass ridge > 0 (e.g.
    1e-3): the real-column augmentation rows become
    ridge · max_colnorm(A_b) · I, bounding ‖G‖ by ~‖Z‖/(ridge·scale) at
    the cost of an O(ridge²·κ²) relative perturbation on well-conditioned
    directions.
    """
    q, rr = solve_G_factor_batched(a, col_mask, ridge=ridge)
    return solve_G_from_factors(q, rr, z, col_mask)


@jax.jit
def solve_G_factor_batched(a, col_mask=None, ridge: float = 0.0):
    """Factor half of `solve_G_batched`: the batched reduced QR of the
    augmented anchor stacks. Returns (q (B, r+m_max, m_max),
    rr (B, m_max, m_max)).

    The factors depend only on the anchors, never on the target Z — the
    incremental-onboarding path caches them per tenant so a Z refresh
    (a new tenant shifted the central target) re-solves every G with
    `solve_G_from_factors` alone: one triangular solve per tenant, zero
    re-factorizations.
    """
    a = a.astype(jnp.float32)
    b, r, m_max = a.shape
    if col_mask is None:
        col_mask = jnp.ones((b, m_max), dtype=bool)
    maskf = col_mask.astype(jnp.float32)              # (B, m_max)
    scale = jnp.sqrt(jnp.max(jnp.sum(a * a, axis=1), axis=-1))  # (B,)
    diag = (1.0 - maskf) + maskf * (ridge * scale[:, None])
    aug = diag[:, :, None] * jnp.eye(m_max, dtype=jnp.float32)[None]
    a_aug = jnp.concatenate([a, aug], axis=1)         # (B, r+m_max, m_max)
    return jnp.linalg.qr(a_aug)                       # reduced, batched


@jax.jit
def solve_G_from_factors(q, rr, z, col_mask=None):
    """Apply half of `solve_G_batched`: G = R⁻¹ Qᵀ [Z; 0] from cached QR
    factors. z: (r, m_hat) shared target or (B, r, m_hat) per-batch."""
    b, _, m_max = rr.shape
    if z.ndim == 2:
        z = jnp.broadcast_to(z[None], (b,) + z.shape)
    z = z.astype(jnp.float32)
    if col_mask is None:
        col_mask = jnp.ones((b, m_max), dtype=bool)
    z_aug = jnp.concatenate(
        [z, jnp.zeros((b, m_max, z.shape[-1]), z.dtype)], axis=1)
    rhs = jnp.einsum("bnm,bnh->bmh", q, z_aug)
    G = jax.scipy.linalg.solve_triangular(rr, rhs, lower=False)
    return G * col_mask[:, :, None]
