"""Oracle for the Gram reduction: G = A^T A in fp32."""
from __future__ import annotations

import jax.numpy as jnp


def gram_reference(a: jnp.ndarray) -> jnp.ndarray:
    """a: (r, m) -> (m, m) fp32."""
    af = a.astype(jnp.float32)
    return af.T @ af


def gram_batched_reference(a: jnp.ndarray) -> jnp.ndarray:
    """a: (B, r, m) -> (B, m, m) fp32."""
    af = a.astype(jnp.float32)
    return jnp.einsum("brm,brn->bmn", af, af)
