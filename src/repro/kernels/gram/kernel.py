"""Pallas TPU kernel for the Gram reduction G = A^T A.

This is the TPU-native core of the collaboration-representation protocol
(DESIGN.md §3): instead of a tall-skinny SVD of the stacked anchor
representations à (r × m̃, r ≫ m̃) — host-bound on TPU — we reduce to the
m̃ × m̃ Gram matrix with an MXU-tiled accumulation and eigendecompose that
(core/collab.py). rank-m̂ singular pairs of à are recovered from eigh(G).

`gram_batched_pallas` is the one kernel: it computes A_b^T A_b for a whole
stack of (group- or user-) matrices in a single launch — grid
(B, m/BM, m/BN, r/BR) with the batch index outermost and the reduction axis
innermost/sequential over a fp32 VMEM accumulator, so each batch element
reuses the same MXU-tiled reduction and the per-call dispatch overhead is
paid once instead of B times. BM=BN=BR=256 → blocks 3×256×256×4 = 768 KiB
VMEM. The single-matrix `gram_pallas` is the B=1 special case.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_batched_kernel(a1_ref, a2_ref, o_ref, acc_scr):
    ri = pl.program_id(3)
    nr = pl.num_programs(3)

    @pl.when(ri == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    a1 = a1_ref[0].astype(jnp.float32)        # (BR, BM)
    a2 = a2_ref[0].astype(jnp.float32)        # (BR, BN)
    acc_scr[...] += jax.lax.dot_general(
        a1, a2, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ri == nr - 1)
    def _finish():
        o_ref[0] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_r", "interpret"))
def gram_batched_pallas(a, *, block_m: int = 256, block_r: int = 256,
                        interpret: bool = False):
    """a: (B, r, m) -> stacked A_b^T A_b (B, m, m) fp32, one launch.
    Pads r and m up to block multiples."""
    b, r, m = a.shape
    bm = min(block_m, m)
    br = min(block_r, r)
    pad_r = (-r) % br
    pad_m = (-m) % bm
    if pad_r or pad_m:
        a = jnp.pad(a, ((0, 0), (0, pad_r), (0, pad_m)))
    _, R, M = a.shape
    grid = (b, M // bm, M // bm, R // br)

    out = pl.pallas_call(
        _gram_batched_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, br, bm), lambda bi, mi, ni, ri: (bi, ri, mi)),
            pl.BlockSpec((1, br, bm), lambda bi, mi, ni, ri: (bi, ri, ni)),
        ],
        out_specs=pl.BlockSpec((1, bm, bm), lambda bi, mi, ni, ri: (bi, mi, ni)),
        out_shape=jax.ShapeDtypeStruct((b, M, M), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bm), jnp.float32)],
        interpret=interpret,
    )(a, a)
    return out[:, :m, :m]


@functools.partial(jax.jit, static_argnames=("block_m", "block_r", "interpret"))
def gram_pallas(a, *, block_m: int = 256, block_r: int = 256,
                interpret: bool = False):
    """a: (r, m) -> A^T A (m, m) fp32 — the B=1 case of the batched kernel."""
    return gram_batched_pallas(a[None], block_m=block_m, block_r=block_r,
                               interpret=interpret)[0]
