"""Pallas TPU kernel for the Gram reduction G = A^T A.

This is the TPU-native core of the collaboration-representation protocol
(DESIGN.md §3): instead of a tall-skinny SVD of the stacked anchor
representations à (r × m̃, r ≫ m̃) — host-bound on TPU — we reduce to the
m̃ × m̃ Gram matrix with an MXU-tiled accumulation and eigendecompose that
(core/collab.py). rank-m̂ singular pairs of à are recovered from eigh(G).

Grid: (m/BM, m/BN, r/BR) with the reduction axis innermost/sequential and a
fp32 VMEM accumulator. BM=BN=BR=256 → blocks 3×256×256×4 = 768 KiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gram_kernel(a1_ref, a2_ref, o_ref, acc_scr):
    ri = pl.program_id(2)
    nr = pl.num_programs(2)

    @pl.when(ri == 0)
    def _init():
        acc_scr[...] = jnp.zeros_like(acc_scr)

    a1 = a1_ref[...].astype(jnp.float32)      # (BR, BM)
    a2 = a2_ref[...].astype(jnp.float32)      # (BR, BN)
    acc_scr[...] += jax.lax.dot_general(
        a1, a2, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(ri == nr - 1)
    def _finish():
        o_ref[...] = acc_scr[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_r", "interpret"))
def gram_pallas(a, *, block_m: int = 256, block_r: int = 256,
                interpret: bool = False):
    """a: (r, m) -> A^T A (m, m) fp32. Pads r and m up to block multiples."""
    r, m = a.shape
    bm = min(block_m, m)
    br = min(block_r, r)
    pad_r = (-r) % br
    pad_m = (-m) % bm
    if pad_r or pad_m:
        a = jnp.pad(a, ((0, pad_r), (0, pad_m)))
    R, M = a.shape
    grid = (M // bm, M // bm, R // br)

    out = pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((br, bm), lambda mi, ni, ri: (ri, mi)),
            pl.BlockSpec((br, bm), lambda mi, ni, ri: (ri, ni)),
        ],
        out_specs=pl.BlockSpec((bm, bm), lambda mi, ni, ri: (mi, ni)),
        out_shape=jax.ShapeDtypeStruct((M, M), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bm), jnp.float32)],
        interpret=interpret,
    )(a, a)
    return out[:m, :m]
