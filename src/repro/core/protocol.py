"""Algorithm 1: the full FedDCL protocol, end to end.

Data layout mirrors the paper: Xs[i][j] is the raw data of user (i, j)
(group i = intra-group DC server i, user j inside it). The orchestration
below simulates the three roles in-process but preserves the exact
communication pattern — what each message contains is exactly what the
paper allows to cross each trust boundary:

  user (i,j)  --{X̃_j^(i), Ã_j^(i), Y_j^(i)}-->  DC server i      (once)
  DC server i --{B̃^(i)}------------------------>  FL server       (once)
  FL server   --{Z}----------------------------->  DC servers      (once)
  DC servers  <==federated rounds on X̂==>        FL server        (iterative)
  DC server i --{G_j^(i), h}-------------------->  user (i,j)      (once)

`CommLog` records every message and its payload bytes, which backs the
communication-cost benchmark (benchmarks/comm_cost.py) and the paper's
"each user communicates exactly twice" claim.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import collab
from repro.core.anchor import make_anchor
from repro.core.mappings import LinearMap, fit_mapping


@dataclass
class CommEvent:
    src: str
    dst: str
    payload: str
    nbytes: int


@dataclass
class CommLog:
    events: List[CommEvent] = field(default_factory=list)

    def log(self, src: str, dst: str, payload: str, *arrays) -> None:
        nbytes = int(sum(np.asarray(a).nbytes for a in arrays))
        self.events.append(CommEvent(src, dst, payload, nbytes))

    def user_round_trips(self) -> Dict[str, int]:
        """Cross-institution communications per user — the paper's claim is
        exactly 2 (upload step 4, download step 15)."""
        counts: Dict[str, int] = {}
        for e in self.events:
            for node in (e.src, e.dst):
                if node.startswith("user"):
                    counts[node] = counts.get(node, 0) + 1
        return counts

    def total_bytes(self, match: Optional[Callable[[CommEvent], bool]] = None) -> int:
        return sum(e.nbytes for e in self.events if match is None or match(e))


@dataclass
class OnboardState:
    """Maintained protocol state enabling incremental tenant onboarding
    (DESIGN.md §10) — everything a from-scratch `run_protocol` would have
    to recompute, kept warm so a new user/silo joins at the cost of ITS OWN
    step-2/3 work plus cheap blocked updates:

      inter_A / inter_X — every user's anchor/data intermediate
          representations (step 2 never re-run for existing tenants)
      grams     — per-group Gram of the stacked anchors, grown by blocked
          cross-products on onboarding (collab.gram_update_blocked)
      bases_B   — per-group B̃^(i); only the group that gained a tenant
          re-derives its basis (small eigh of the maintained Gram)
      g_factors — per-group cached QR factors of every user's Ã_j: a Z
          refresh re-solves ALL G's with triangular solves only
    """
    seed: int
    m_tilde: int
    m_hat: int
    mapping_kind: str
    backend: Any                                 # svd_backend as given
    inter_A: List[List[np.ndarray]]
    inter_X: List[List[np.ndarray]]
    grams: List[np.ndarray]
    bases_B: List[np.ndarray]
    g_factors: List[Any]


@dataclass
class FedDCLSetup:
    """Everything produced by protocol steps 1–3 (before model training)."""
    anchor: np.ndarray
    mappings: List[List[LinearMap]]              # f_j^(i)
    Gs: List[List[np.ndarray]]                   # G_j^(i)
    collab_X: List[np.ndarray]                   # X̂^(i) per group (stacked users)
    collab_Y: List[np.ndarray]                   # Y^(i) per group
    comm: CommLog
    m_hat: int
    Z: Optional[np.ndarray] = None               # central target (r, m̂)
    onboard: Optional[OnboardState] = None       # run_protocol(onboard=True)

    def user_transform(self, i: int, j: int) -> Callable[[np.ndarray], np.ndarray]:
        """x -> f_j^(i)(x) G_j^(i) — the per-user input map of the final
        integrated model t_j^(i)(X) = h(f(X) G)."""
        f, G = self.mappings[i][j], self.Gs[i][j]
        return lambda X: f(np.asarray(X, np.float64)) @ G

    def fed_silos(self) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Step 4 input: per-DC-server (X̂^(i), Y^(i)) silo pairs, ready for
        core.federated.run_federated (either engine — the scan engine pads
        and moves them device-resident in one shot)."""
        return list(zip(self.collab_X, self.collab_Y))

    @property
    def num_groups(self) -> int:
        return len(self.mappings)

    def num_users(self, i: Optional[int] = None) -> int:
        if i is not None:
            return len(self.mappings[i])
        return sum(len(row) for row in self.mappings)

    # -- incremental onboarding (DESIGN.md §10) ----------------------------

    def _require_onboard(self) -> OnboardState:
        if self.onboard is None:
            raise RuntimeError(
                "this FedDCLSetup was built without onboarding state — "
                "run_protocol(..., onboard=True) (FedDCL.fit does)")
        return self.onboard

    def onboard_user(self, i: int, X_new: np.ndarray,
                     Y_new: np.ndarray) -> int:
        """A new user joins existing group i on a LIVE setup: fits only the
        newcomer's private map, extends group i's Gram by blocked
        cross-products, re-derives that group's basis from the small
        maintained Gram (never the O(r·W²) anchor reduction), refreshes the
        tiny central SVD with the protocol's exact RNG streams, and
        re-solves G's from cached QR factors — only the newcomer is ever
        factored. Equal to a from-scratch `run_protocol` over the full
        roster against the same anchor (≤1e-8 host / ≤1e-5 device, tested).

        Returns the new user's index j within group i.
        """
        st = self._require_onboard()
        be = collab.get_backend(st.backend)
        j = len(self.mappings[i])
        X_new = np.asarray(X_new, np.float64)
        f = fit_mapping(st.mapping_kind, X_new, st.m_tilde,
                        seed=st.seed * 1009 + i * 101 + j)
        Xt, At = f(X_new), f(self.anchor)
        self.comm.log(f"user({i},{j})", f"dc({i})", "X~,A~,Y", Xt, At, Y_new)
        A_old = np.concatenate(st.inter_A[i], axis=1)
        st.grams[i] = be.gram_update_blocked(st.grams[i], A_old, At)
        st.inter_A[i].append(At)
        st.inter_X[i].append(Xt)
        self.mappings[i].append(f)
        fac = be.factor_G_append(st.g_factors[i], At)
        if fac is None:                 # wider than the factored pad width
            fac = be.factor_G_many(st.inter_A[i])
        st.g_factors[i] = fac
        self._refresh_group_basis(i)
        self._refresh_central_and_G(changed_groups=(i,))
        self.collab_Y[i] = np.concatenate(
            [self.collab_Y[i], np.asarray(Y_new)], axis=0)
        return j

    def onboard_silo(self, Xs_new: Sequence[np.ndarray],
                     Ys_new: Sequence[np.ndarray]) -> int:
        """A whole new DC group (institution) joins: step 2 runs for ITS
        users only, its Gram/basis are computed fresh (they are new), the
        central target is refreshed over d+1 bases, and every existing
        user's G is re-solved from cached factors. Returns the new group
        index i."""
        st = self._require_onboard()
        be = collab.get_backend(st.backend)
        i = len(self.mappings)
        row_f, row_x, row_a = [], [], []
        for j, X in enumerate(Xs_new):
            X = np.asarray(X, np.float64)
            f = fit_mapping(st.mapping_kind, X, st.m_tilde,
                            seed=st.seed * 1009 + i * 101 + j)
            row_f.append(f)
            Xt, At = f(X), f(self.anchor)
            row_x.append(Xt)
            row_a.append(At)
            self.comm.log(f"user({i},{j})", f"dc({i})", "X~,A~,Y",
                          Xt, At, Ys_new[j])
        A = np.concatenate(row_a, axis=1)
        st.inter_A.append(row_a)
        st.inter_X.append(row_x)
        st.grams.append(be.gram(A))
        st.g_factors.append(be.factor_G_many(row_a))
        self.mappings.append(row_f)
        self.Gs.append([])
        rng = np.random.default_rng(st.seed * 31 + i)
        svd = be.topk_svd(A, st.m_hat)
        st.bases_B.append(collab._basis_from_svd(
            svd, rng, [a.shape[1] for a in row_a]).B)
        self.collab_X.append(np.zeros((0, st.m_hat)))   # filled by refresh
        self.collab_Y.append(np.concatenate(
            [np.asarray(y) for y in Ys_new], axis=0))
        self._refresh_central_and_G(changed_groups=(i,))
        return i

    def _refresh_group_basis(self, i: int) -> None:
        """Re-derive B̃^(i) from the MAINTAINED Gram — eigh of a (W, W)
        matrix plus one (r, W)·(W, m̂) recovery matmul — replaying the same
        per-group RNG stream `run_protocol` would use."""
        st = self.onboard
        be = collab.get_backend(st.backend)
        A = np.concatenate(st.inter_A[i], axis=1)
        svd = be.topk_svd_from_gram(A, st.grams[i], st.m_hat)
        rng = np.random.default_rng(st.seed * 31 + i)
        st.bases_B[i] = collab._basis_from_svd(
            svd, rng, [a.shape[1] for a in st.inter_A[i]]).B

    def _refresh_central_and_G(self, changed_groups: Sequence[int] = ()) -> None:
        """Steps 3b/3c/12 after a basis changed: recompute the (tiny)
        central SVD → Z, re-solve every user's G from cached QR factors
        (one batched triangular solve per group), and refresh the
        collaboration representations X̂ = X̃ G from the cached X̃."""
        st = self.onboard
        be = collab.get_backend(st.backend)
        for i in changed_groups:
            self.comm.log(f"dc({i})", "fl", "B~", st.bases_B[i])
        target = collab.central_target(
            [collab.GroupBasis(B=B) for B in st.bases_B],
            st.m_hat, st.seed * 57, backend=st.backend)
        self.Z = target.Z
        d = len(st.inter_A)
        for i in range(d):
            self.comm.log("fl", f"dc({i})", "Z", target.Z)
            self.Gs[i] = be.solve_G_factors(st.g_factors[i], target.Z)
        flat_X = [x for row in st.inter_X for x in row]
        flat_G = [g for row in self.Gs for g in row]
        flat_XG = collab.apply_G_all(flat_X, flat_G, backend=st.backend)
        k = 0
        for i in range(d):
            c_i = len(st.inter_X[i])
            self.collab_X[i] = np.concatenate(flat_XG[k:k + c_i], axis=0)
            k += c_i


def run_protocol(
    Xs: Sequence[Sequence[np.ndarray]],
    Ys: Sequence[Sequence[np.ndarray]],
    *,
    m_tilde: int,
    m_hat: Optional[int] = None,
    anchor_r: int = 2000,
    anchor_kind: str = "uniform",
    mapping_kind: str = "pca_rot",
    seed: int = 0,
    svd_backend: str = "host",
    fixed_W: Optional[np.ndarray] = None,
    anchor: Optional[np.ndarray] = None,
    onboard: bool = False,
) -> FedDCLSetup:
    """Steps 1–3 + 12 of Algorithm 1 (everything except the FL training,
    which core/federated.run_federated performs on the returned collab_X).

    `svd_backend` selects the step-3 engine (collab.CollabBackend):
    "host" is the serial NumPy float64 reference; "device" (alias "tpu")
    runs one batched Gram+eigh launch for all d groups and one batched QR
    least-squares for all users — no per-group or per-user Python-loop
    linear algebra on the hot path.

    `anchor` supplies a pre-agreed anchor dataset instead of deriving one
    from the pooled data — the protocol's real deployment shape (the anchor
    is fixed once and later tenants adopt it) and what makes incremental
    onboarding exactly comparable to a from-scratch rerun.

    `onboard=True` additionally retains the `OnboardState` (per-user
    intermediate representations, per-group Grams, cached G factors) that
    `FedDCLSetup.onboard_user`/`onboard_silo` need — a little extra setup
    compute and memory, so it is opt-in (FedDCL.fit opts in)."""
    d = len(Xs)
    m = Xs[0][0].shape[1]
    m_hat = m_hat or m_tilde
    comm = CommLog()

    # ---- Step 1: shared anchor (same seed everywhere) --------------------
    if anchor is None:
        allX = np.concatenate([np.concatenate(list(g), axis=0) for g in Xs],
                              axis=0)
        anchor = make_anchor(anchor_kind, seed, anchor_r,
                             feat_min=allX.min(0), feat_max=allX.max(0),
                             public_sample=allX[:: max(1, len(allX) // 512)])
    else:
        anchor = np.asarray(anchor, np.float64)

    # ---- Step 2: private maps + intermediate representations -------------
    mappings: List[List[LinearMap]] = []
    inter_X: List[List[np.ndarray]] = []
    inter_A: List[List[np.ndarray]] = []
    for i in range(d):
        row_f, row_x, row_a = [], [], []
        for j in range(len(Xs[i])):
            f = fit_mapping(mapping_kind, np.asarray(Xs[i][j], np.float64),
                            m_tilde, seed=seed * 1009 + i * 101 + j, W=fixed_W)
            row_f.append(f)
            Xt, At = f(np.asarray(Xs[i][j], np.float64)), f(anchor)
            row_x.append(Xt)
            row_a.append(At)
            comm.log(f"user({i},{j})", f"dc({i})", "X~,A~,Y", Xt, At, Ys[i][j])
        mappings.append(row_f)
        inter_X.append(row_x)
        inter_A.append(row_a)

    # ---- Step 3a: intra-group bases -> central server --------------------
    # One batched Gram+eigh launch for all d groups on the device backend
    # (zero-padded to the max group width); serial LAPACK loop on host.
    bases = collab.intra_group_bases(
        inter_A, m_hat, seeds=[seed * 31 + i for i in range(d)],
        backend=svd_backend)
    for i, gb in enumerate(bases):
        comm.log(f"dc({i})", "fl", "B~", gb.B)

    # ---- Step 3b: central target Z -> DC servers --------------------------
    target = collab.central_target(bases, m_hat, seed * 57, backend=svd_backend)
    for i in range(d):
        comm.log("fl", f"dc({i})", "Z", target.Z)

    # ---- Step 3c + 12: per-user G, collaboration representations ----------
    # All users of the protocol solved in ONE batched QR call on device, and
    # all per-user X̂ = X̃ G products computed in ONE padded batched matmul
    # (collab.apply_G_all) instead of a per-user host loop.
    flat_A = [inter_A[i][j] for i in range(d) for j in range(len(Xs[i]))]
    flat_G = collab.solve_G_all(flat_A, target.Z, backend=svd_backend)
    flat_X = [inter_X[i][j] for i in range(d) for j in range(len(Xs[i]))]
    flat_XG = collab.apply_G_all(flat_X, flat_G, backend=svd_backend)
    Gs: List[List[np.ndarray]] = []
    collab_X: List[np.ndarray] = []
    collab_Y: List[np.ndarray] = []
    k = 0
    for i in range(d):
        c_i = len(Xs[i])
        Gs.append(flat_G[k:k + c_i])
        collab_X.append(np.concatenate(flat_XG[k:k + c_i], axis=0))
        collab_Y.append(np.concatenate(list(Ys[i]), axis=0))
        k += c_i

    state = None
    if onboard:
        be = collab.get_backend(svd_backend)
        stacked = [np.concatenate(row, axis=1) for row in inter_A]
        state = OnboardState(
            seed=seed, m_tilde=m_tilde, m_hat=m_hat,
            mapping_kind=mapping_kind, backend=svd_backend,
            inter_A=[list(row) for row in inter_A],
            inter_X=[list(row) for row in inter_X],
            grams=[be.gram(A) for A in stacked],
            bases_B=[gb.B for gb in bases],
            g_factors=[be.factor_G_many(row) for row in inter_A])

    return FedDCLSetup(anchor=anchor, mappings=mappings, Gs=Gs,
                       collab_X=collab_X, collab_Y=collab_Y, comm=comm,
                       m_hat=m_hat, Z=target.Z, onboard=state)


def finalize_user_models(setup: FedDCLSetup, h: Callable[[np.ndarray], np.ndarray],
                         h_params_bytes: int = 0):
    """Step 5/15: return t_j^(i)(X) = h(f_j^(i)(X) G_j^(i)) per user and log
    the download leg (the user's 2nd and final communication)."""
    models = []
    for i in range(len(setup.mappings)):
        row = []
        for j in range(len(setup.mappings[i])):
            tr = setup.user_transform(i, j)
            setup.comm.log(f"dc({i})", f"user({i},{j})", "G,h",
                           setup.Gs[i][j], np.zeros(h_params_bytes // 8 + 1))
            row.append(lambda X, tr=tr: h(tr(X)))
        models.append(row)
    return models
