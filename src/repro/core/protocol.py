"""Algorithm 1: the full FedDCL protocol, end to end.

Data layout mirrors the paper: Xs[i][j] is the raw data of user (i, j)
(group i = intra-group DC server i, user j inside it). The orchestration
below simulates the three roles in-process but preserves the exact
communication pattern — what each message contains is exactly what the
paper allows to cross each trust boundary:

  user (i,j)  --{X̃_j^(i), Ã_j^(i), Y_j^(i)}-->  DC server i      (once)
  DC server i --{B̃^(i)}------------------------>  FL server       (once)
  FL server   --{Z}----------------------------->  DC servers      (once)
  DC servers  <==federated rounds on X̂==>        FL server        (iterative)
  DC server i --{G_j^(i), h}-------------------->  user (i,j)      (once)

`CommLog` records every message and its payload bytes, which backs the
communication-cost benchmark (benchmarks/comm_cost.py) and the paper's
"each user communicates exactly twice" claim.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import collab
from repro.core.anchor import make_anchor
from repro.core.mappings import LinearMap, fit_mapping


@dataclass
class CommEvent:
    src: str
    dst: str
    payload: str
    nbytes: int


@dataclass
class CommLog:
    events: List[CommEvent] = field(default_factory=list)

    def log(self, src: str, dst: str, payload: str, *arrays) -> None:
        nbytes = int(sum(np.asarray(a).nbytes for a in arrays))
        self.events.append(CommEvent(src, dst, payload, nbytes))

    def user_round_trips(self) -> Dict[str, int]:
        """Cross-institution communications per user — the paper's claim is
        exactly 2 (upload step 4, download step 15)."""
        counts: Dict[str, int] = {}
        for e in self.events:
            for node in (e.src, e.dst):
                if node.startswith("user"):
                    counts[node] = counts.get(node, 0) + 1
        return counts

    def total_bytes(self, match: Optional[Callable[[CommEvent], bool]] = None) -> int:
        return sum(e.nbytes for e in self.events if match is None or match(e))


@dataclass
class FedDCLSetup:
    """Everything produced by protocol steps 1–3 (before model training)."""
    anchor: np.ndarray
    mappings: List[List[LinearMap]]              # f_j^(i)
    Gs: List[List[np.ndarray]]                   # G_j^(i)
    collab_X: List[np.ndarray]                   # X̂^(i) per group (stacked users)
    collab_Y: List[np.ndarray]                   # Y^(i) per group
    comm: CommLog
    m_hat: int
    Z: Optional[np.ndarray] = None               # central target (r, m̂)

    def user_transform(self, i: int, j: int) -> Callable[[np.ndarray], np.ndarray]:
        """x -> f_j^(i)(x) G_j^(i) — the per-user input map of the final
        integrated model t_j^(i)(X) = h(f(X) G)."""
        f, G = self.mappings[i][j], self.Gs[i][j]
        return lambda X: f(np.asarray(X, np.float64)) @ G

    def fed_silos(self) -> List[Tuple[np.ndarray, np.ndarray]]:
        """Step 4 input: per-DC-server (X̂^(i), Y^(i)) silo pairs, ready for
        core.federated.run_federated (either engine — the scan engine pads
        and moves them device-resident in one shot)."""
        return list(zip(self.collab_X, self.collab_Y))


def run_protocol(
    Xs: Sequence[Sequence[np.ndarray]],
    Ys: Sequence[Sequence[np.ndarray]],
    *,
    m_tilde: int,
    m_hat: Optional[int] = None,
    anchor_r: int = 2000,
    anchor_kind: str = "uniform",
    mapping_kind: str = "pca_rot",
    seed: int = 0,
    svd_backend: str = "host",
    fixed_W: Optional[np.ndarray] = None,
) -> FedDCLSetup:
    """Steps 1–3 + 12 of Algorithm 1 (everything except the FL training,
    which core/federated.run_federated performs on the returned collab_X).

    `svd_backend` selects the step-3 engine (collab.CollabBackend):
    "host" is the serial NumPy float64 reference; "device" (alias "tpu")
    runs one batched Gram+eigh launch for all d groups and one batched QR
    least-squares for all users — no per-group or per-user Python-loop
    linear algebra on the hot path."""
    d = len(Xs)
    m = Xs[0][0].shape[1]
    m_hat = m_hat or m_tilde
    comm = CommLog()

    # ---- Step 1: shared anchor (same seed everywhere) --------------------
    allX = np.concatenate([np.concatenate(list(g), axis=0) for g in Xs], axis=0)
    anchor = make_anchor(anchor_kind, seed, anchor_r,
                         feat_min=allX.min(0), feat_max=allX.max(0),
                         public_sample=allX[:: max(1, len(allX) // 512)])

    # ---- Step 2: private maps + intermediate representations -------------
    mappings: List[List[LinearMap]] = []
    inter_X: List[List[np.ndarray]] = []
    inter_A: List[List[np.ndarray]] = []
    for i in range(d):
        row_f, row_x, row_a = [], [], []
        for j in range(len(Xs[i])):
            f = fit_mapping(mapping_kind, np.asarray(Xs[i][j], np.float64),
                            m_tilde, seed=seed * 1009 + i * 101 + j, W=fixed_W)
            row_f.append(f)
            Xt, At = f(np.asarray(Xs[i][j], np.float64)), f(anchor)
            row_x.append(Xt)
            row_a.append(At)
            comm.log(f"user({i},{j})", f"dc({i})", "X~,A~,Y", Xt, At, Ys[i][j])
        mappings.append(row_f)
        inter_X.append(row_x)
        inter_A.append(row_a)

    # ---- Step 3a: intra-group bases -> central server --------------------
    # One batched Gram+eigh launch for all d groups on the device backend
    # (zero-padded to the max group width); serial LAPACK loop on host.
    bases = collab.intra_group_bases(
        inter_A, m_hat, seeds=[seed * 31 + i for i in range(d)],
        backend=svd_backend)
    for i, gb in enumerate(bases):
        comm.log(f"dc({i})", "fl", "B~", gb.B)

    # ---- Step 3b: central target Z -> DC servers --------------------------
    target = collab.central_target(bases, m_hat, seed * 57, backend=svd_backend)
    for i in range(d):
        comm.log("fl", f"dc({i})", "Z", target.Z)

    # ---- Step 3c + 12: per-user G, collaboration representations ----------
    # All users of the protocol solved in ONE batched QR call on device, and
    # all per-user X̂ = X̃ G products computed in ONE padded batched matmul
    # (collab.apply_G_all) instead of a per-user host loop.
    flat_A = [inter_A[i][j] for i in range(d) for j in range(len(Xs[i]))]
    flat_G = collab.solve_G_all(flat_A, target.Z, backend=svd_backend)
    flat_X = [inter_X[i][j] for i in range(d) for j in range(len(Xs[i]))]
    flat_XG = collab.apply_G_all(flat_X, flat_G, backend=svd_backend)
    Gs: List[List[np.ndarray]] = []
    collab_X: List[np.ndarray] = []
    collab_Y: List[np.ndarray] = []
    k = 0
    for i in range(d):
        c_i = len(Xs[i])
        Gs.append(flat_G[k:k + c_i])
        collab_X.append(np.concatenate(flat_XG[k:k + c_i], axis=0))
        collab_Y.append(np.concatenate(list(Ys[i]), axis=0))
        k += c_i

    return FedDCLSetup(anchor=anchor, mappings=mappings, Gs=Gs,
                       collab_X=collab_X, collab_Y=collab_Y, comm=comm,
                       m_hat=m_hat, Z=target.Z)


def finalize_user_models(setup: FedDCLSetup, h: Callable[[np.ndarray], np.ndarray],
                         h_params_bytes: int = 0):
    """Step 5/15: return t_j^(i)(X) = h(f_j^(i)(X) G_j^(i)) per user and log
    the download leg (the user's 2nd and final communication)."""
    models = []
    for i in range(len(setup.mappings)):
        row = []
        for j in range(len(setup.mappings[i])):
            tr = setup.user_transform(i, j)
            setup.comm.log(f"dc({i})", f"user({i},{j})", "G,h",
                           setup.Gs[i][j], np.zeros(h_params_bytes // 8 + 1))
            row.append(lambda X, tr=tr: h(tr(X)))
        models.append(row)
    return models
