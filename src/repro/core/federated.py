"""Step 4 of FedDCL: federated learning between intra-group DC servers.

Two realizations of the same aggregation schedule:

1. **Host simulation** (`run_federated`) — faithful to the paper's §4: d
   DC-server silos, each running E local epochs of minibatch training per
   round, parameters averaged (sample-weighted FedAvg) each round. Supports
   FedAvg / FedProx (proximal term) / FedSGD (one aggregated gradient step
   per round). Used by the tabular benchmarks.

2. **Mesh collectives** (`silo_vmap_step`, `fedavg_sync`) — the production
   form on the TPU mesh: parameters carry a leading silo dim sharded over
   the silo mesh axis ("pod" on multi-pod, "data" on single-pod); local
   steps are vmapped over that dim (provably zero cross-silo collectives)
   and the round boundary is one mean-reduce (GSPMD lowers it to an
   all-reduce over the silo axis only). Used by launch/train.py.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import Optimizer, apply_updates


# ==========================================================================
# 1. Host-level silo simulation (paper-faithful)
# ==========================================================================

@dataclass
class FLResult:
    params: Any
    history: List[Dict[str, float]]


def fedavg_average(params_list: Sequence[Any], weights: Sequence[float]) -> Any:
    w = np.asarray(weights, np.float64)
    w = w / w.sum()
    return jax.tree.map(
        lambda *ps: sum(wi * p.astype(jnp.float32) for wi, p in zip(w, ps)).astype(ps[0].dtype),
        *params_list,
    )


def run_federated(
    loss_fn: Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    init_params: Any,
    silo_data: Sequence[Tuple[np.ndarray, np.ndarray]],
    *,
    opt: Optimizer,
    rounds: int,
    local_epochs: int,
    batch_size: int = 32,
    aggregator: str = "fedavg",
    fedprox_mu: float = 0.0,
    seed: int = 0,
    eval_fn: Optional[Callable[[Any], Dict[str, float]]] = None,
) -> FLResult:
    """Generic federated loop over host-resident silo datasets."""
    rng = np.random.default_rng(seed)
    global_params = init_params

    if aggregator == "fedprox":
        def local_loss(p, x, y, ref):
            prox = sum(
                jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
                for a, b in zip(jax.tree_util.tree_leaves(p),
                                jax.tree_util.tree_leaves(ref)))
            return loss_fn(p, x, y) + 0.5 * fedprox_mu * prox
    else:
        def local_loss(p, x, y, ref):
            return loss_fn(p, x, y)

    @jax.jit
    def sgd_step(p, opt_state, x, y, ref):
        loss, grads = jax.value_and_grad(local_loss)(p, x, y, ref)
        updates, opt_state = opt.update(grads, opt_state, p)
        return apply_updates(p, updates), opt_state, loss

    @jax.jit
    def grad_only(p, x, y):
        return jax.grad(loss_fn)(p, x, y)

    history: List[Dict[str, float]] = []
    sizes = [x.shape[0] for x, _ in silo_data]
    fedsgd_state = opt.init(global_params) if aggregator == "fedsgd" else None
    for rnd in range(rounds):
        if aggregator == "fedsgd":
            grads = [grad_only(global_params, jnp.asarray(x), jnp.asarray(y))
                     for x, y in silo_data]
            g = fedavg_average(grads, sizes)
            updates, fedsgd_state = opt.update(g, fedsgd_state, global_params)
            global_params = apply_updates(global_params, updates)
        else:
            locals_: List[Any] = []
            last_loss = 0.0
            for (x, y) in silo_data:
                p = global_params
                opt_state = opt.init(p)
                n = x.shape[0]
                for _ in range(local_epochs):
                    perm = rng.permutation(n)
                    for s in range(0, n, batch_size):
                        sl = perm[s : s + batch_size]
                        p, opt_state, last_loss = sgd_step(
                            p, opt_state, jnp.asarray(x[sl]), jnp.asarray(y[sl]),
                            global_params)
                locals_.append(p)
            global_params = fedavg_average(locals_, sizes)
        rec = {"round": rnd, "loss": float(last_loss) if aggregator != "fedsgd" else float("nan")}
        if eval_fn is not None:
            rec.update(eval_fn(global_params))
        history.append(rec)
    return FLResult(params=global_params, history=history)


# ==========================================================================
# 2. Mesh-level federated collectives (production / dry-run form)
# ==========================================================================

def silo_replicate(params: Any, num_silos: int) -> Any:
    """Give every leaf a leading silo dim (identical start, paper Step 4)."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (num_silos,) + p.shape), params)


def silo_vmap_step(step_fn: Callable) -> Callable:
    """vmap a per-silo (params, opt_state, batch) -> (params, opt_state,
    metrics) step over the leading silo dim. The resulting HLO contains no
    collective over the silo mesh axis — verified by tests/test_federated.py.
    """
    return jax.vmap(step_fn, in_axes=0, out_axes=0)


def fedavg_sync(silo_params: Any, weights: Optional[jnp.ndarray] = None) -> Any:
    """Round boundary: average parameters across the silo dim and broadcast
    back. Under GSPMD with the silo dim sharded over the silo mesh axis this
    lowers to exactly one all-reduce over that axis per leaf."""
    def avg(p):
        pf = p.astype(jnp.float32)
        if weights is None:
            mean = jnp.mean(pf, axis=0, keepdims=True)
        else:
            w = (weights / jnp.sum(weights)).astype(jnp.float32)
            mean = jnp.tensordot(w, pf, axes=(0, 0))[None]
        return jnp.broadcast_to(mean, p.shape).astype(p.dtype)

    return jax.tree.map(avg, silo_params)


def fedprox_regularizer(params: Any, ref_params: Any, mu: float) -> jnp.ndarray:
    return 0.5 * mu * sum(
        jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(ref_params)))
