"""Step 4 of FedDCL: federated learning between intra-group DC servers.

ONE trainer serves every method (`run_federated`; Centralized / Local / DC
reach it through `baselines.sgd_train`, the d=1 degenerate case), with two
interchangeable engines mirroring the step-3 `CollabBackend` split
(DESIGN.md §3, §4):

  engine="host" — the paper-faithful reference: a NumPy-orchestrated Python
      loop that dispatches one tiny jitted SGD step per minibatch per epoch
      per silo per round (thousands of device launches for a 20-round run).
  engine="scan" — the compiled form: the WHOLE FL phase is one jitted
      program. Silo datasets are zero-padded to a (d, n_slots, m) stack with
      per-sample masks, minibatch order comes from `jax.random.permutation`
      folded from the seed, local epochs and minibatches are inner
      `lax.scan`s with the per-silo step vmapped over the leading silo dim,
      and rounds are an outer `lax.scan` whose boundary is the weighted
      `fedavg_sync`. A 20-round × 4-epoch run is ONE dispatch.

Both engines consume the same padded layout (`pad_silo_data`) and the same
batch schedule (`round_perms`), so with the same seed they agree to float
tolerance on parameters and loss trajectories (tests/test_fed_engine.py).
FedAvg / FedProx / FedSGD all route through the same code path.

The scan engine's compiled unit is a PLAN (`make_fl_plan`): a jitted
program taking ALL tenant data (padded stacks, weights, PRNG key) as
arguments, so executables are reusable across tenants. `PlanCache` stores
plans keyed on the full compile signature with silo/batch axes rounded up
to shape buckets (`run_federated(cache=True)`, DESIGN.md §6) — the
amortization layer that makes sweeps and many-tenant traffic pay the
~1 s trace+compile once instead of per call.

Plans also run MULTI-DEVICE (`make_fl_plan(mesh=...)`, DESIGN.md §7): the
rounds-scan is wrapped in one `shard_map` with the padded silo stack split
over the mesh's silo axes (("pod", "data") jointly on multi-pod meshes)
and params replicated; the local phase is collective-free per shard and
the round boundary lowers to one weighted all-reduce per leaf per
hierarchy level. With `eval_fn`, plans are `StreamedPlan` chunk steps
that bound eval memory to eval_chunk × |params| regardless of rounds
(no more (rounds, |params|) stack inside the scan).

Loss reporting: `history[rnd]["loss"]` is the sample-weighted mean over
silos of each silo's final-local-epoch masked mean loss (the scan engine
carries it through the scan; the host engine accumulates the same sums).

HOSTILE-WORLD federation (DESIGN.md §8): the aggregation boundary can be
made adversarial-robust (`aggregator="median" | "trimmed_mean" | "krum"` —
masked coordinate statistics over the per-silo deltas, computed from a
cross-silo all_gather instead of the weighted psum when sharded), silos can
drop out mid-training (`dropout_rate` / an explicit `availability` matrix —
the schedule is drawn on HOST, outside any shard_map manual region, and
folded into per-round normalized weights so unavailable silos are exact
no-ops under the §4 mask rules), and per-silo deltas can be scaled
(`silo_scale` — the gradient-scaling attacker injection point,
core/privacy.py).

The mesh-collective primitives (`silo_vmap_step`, `fedavg_sync`,
`scan_local_steps`) are the production form on the TPU mesh: parameters
carry a leading silo dim sharded over the silo mesh axis, local steps are
vmapped over that dim (provably zero cross-silo collectives) and the round
boundary is one mean-reduce. launch/steps.py builds its federated round on
top of them.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

from repro.optim import Optimizer, apply_updates
from repro.shardingx.policy import batch_spec


# ==========================================================================
# 1. Shared engine substrate: padded silo layout + batch schedule + step
# ==========================================================================

@dataclass(frozen=True)
class PaddedSilos:
    """Zero-padded device layout shared by both engines.

    X (d, n_slots, m) float32 and Y (d, n_slots[, k]) are the silo datasets
    padded on the sample axis; w (d, n_slots) float32 holds 1.0 on REAL
    samples and 0.0 on padding; sizes (d,) int64 are the real sample counts
    (kept integral — float32 counts silently corrupt FedAvg weights above
    2^24 samples; they are converted to float only at the normalization
    sites, see _norm_weights).
    n_slots = num_batches * batch_size ≥ max_i n_i, so every minibatch has a
    static shape and an epoch is exactly one permutation of the slot axis.

    The silo axis may carry trailing EMPTY silos (sizes 0, all-padding) and
    the slot axis trailing all-padding batches — how the plan cache buckets
    ragged tenant shapes onto shared executables (pad_silo_data's
    min_silos / min_batches).
    """
    X: np.ndarray
    Y: np.ndarray
    w: np.ndarray
    sizes: np.ndarray
    n_slots: int
    batch_size: int
    num_batches: int

    @property
    def num_silos(self) -> int:
        return self.X.shape[0]

    @property
    def has_padding(self) -> bool:
        return bool(np.any(self.sizes < self.n_slots))


def pad_silo_data(silo_data: Sequence[Tuple[np.ndarray, np.ndarray]],
                  batch_size: Optional[int] = None,
                  fill: float = 0.0,
                  min_batches: int = 0,
                  min_silos: int = 0) -> PaddedSilos:
    """Stack ragged per-silo (X_i, Y_i) into the padded engine layout.

    batch_size=None means full-batch (FedSGD): one batch of n_max slots.
    `fill` sets the value written into padded X rows — 0.0 in production;
    the padding-leak property test passes garbage to prove masks win.
    min_batches / min_silos round the layout UP to a shape bucket (extra
    all-padding batches / extra zero-size silos) so different tenants share
    one compiled executable (the plan cache, DESIGN.md §6). Empty silos get
    sample weight zero everywhere, so they are exact no-ops.
    """
    sizes = np.array([np.asarray(x).shape[0] for x, _ in silo_data], np.int64)
    n_max = int(sizes.max())
    if batch_size is None:
        bs, nb = max(n_max, 1), 1
    else:
        bs = int(batch_size)
        nb = -(-n_max // bs)
    nb = max(nb, int(min_batches), 1)
    n_slots = bs * nb
    d = max(len(silo_data), int(min_silos))
    if d > len(silo_data):
        sizes = np.concatenate([sizes, np.zeros(d - len(silo_data), np.int64)])
    x0, y0 = np.asarray(silo_data[0][0]), np.asarray(silo_data[0][1])
    X = np.full((d, n_slots) + x0.shape[1:], fill, np.float32)
    Y = np.zeros((d, n_slots) + y0.shape[1:], y0.dtype)
    w = np.zeros((d, n_slots), np.float32)
    for i, (xi, yi) in enumerate(silo_data):
        n = np.asarray(xi).shape[0]
        X[i, :n] = np.asarray(xi, np.float32)
        Y[i, :n] = np.asarray(yi)
        w[i, :n] = 1.0
    return PaddedSilos(X=X, Y=Y, w=w, sizes=sizes, n_slots=n_slots,
                       batch_size=bs, num_batches=nb)


def _norm_weights(sizes: np.ndarray) -> np.ndarray:
    """Per-silo FedAvg weights from integral sample counts: normalized on
    host in float64 (exact for any realistic count) and only THEN cast to
    float32 for the device — sizes themselves are never stored as float32,
    which would corrupt counts above 2^24."""
    s = np.asarray(sizes, np.float64)
    return (s / s.sum()).astype(np.float32)


# Tiny-epsilon guard for loss denominators. The old clamp max(Σw, 1.0)
# silently DEFLATED the reported loss whenever an epoch's (or batch's) real
# sample-weight mass was positive but < 1 — e.g. fractional per-sample
# weights fed through a hand-built PaddedSilos/plan. For {0,1} masks the two
# forms are identical (mass is 0 or ≥ 1), so this is numerics-neutral on
# every production layout; tests/test_fed_robust.py pins the corrected
# fractional-weight value on both engines.
_DEN_EPS = 1e-12


def make_dropout_schedule(seed: int, rounds: int, num_silos: int,
                          rate: float,
                          sizes: Optional[np.ndarray] = None) -> np.ndarray:
    """Per-round silo availability mask, (rounds, num_silos) float32 {0,1}.

    Drawn ON HOST (numpy; never inside a compiled program, let alone a
    shard_map manual region — the same rule as the batch-permutation
    schedule, see make_fl_plan's miscompile note) so both engines and every
    sharding of the plan consume the identical schedule. Each (round, silo)
    is an independent Bernoulli(1 - rate) draw; empty silos (sizes 0) are
    never available, and every round is guaranteed at least one available
    REAL silo (the max-draw silo is resurrected) so round weights stay
    normalizable. Stragglers are modeled as round-grained dropout: a silo
    that misses the boundary simply doesn't contribute this round."""
    real = (np.ones(num_silos, bool) if sizes is None
            else np.asarray(sizes) > 0)
    if not real.any():
        raise ValueError("dropout schedule needs at least one real silo")
    rng = np.random.default_rng(np.asarray([seed, 0xD120], np.uint64))
    u = rng.random((rounds, num_silos))
    av = (u >= rate) & real[None, :]
    dead = ~av.any(axis=1)
    if dead.any():
        best = np.argmax(np.where(real[None, :], u, -1.0), axis=1)
        av[dead, best[dead]] = True
    return av.astype(np.float32)


def _round_weights(sizes: np.ndarray, av: Optional[np.ndarray],
                   rounds: int) -> np.ndarray:
    """Per-ROUND aggregation weights, (rounds, d) float32: the sample-count
    weights masked by that round's availability and renormalized over the
    silos that are actually present. With full availability every row equals
    `_norm_weights(sizes)` bit-for-bit (same float64 normalize-then-cast),
    so the no-dropout path is unchanged. Computed on host and fed to plans
    as an ARGUMENT — dropout never enters the executable, so every dropout
    pattern shares one compiled plan."""
    s = np.asarray(sizes, np.float64)
    m = np.broadcast_to(s[None, :], (rounds, len(s))).copy()
    if av is not None:
        m = m * np.asarray(av, np.float64)
    tot = m.sum(axis=1, keepdims=True)
    if np.any(tot <= 0):
        bad = int(np.argmax(tot[:, 0] <= 0))
        raise ValueError(
            f"round {bad} has zero available sample mass — the availability "
            "schedule must keep at least one real silo per round "
            "(make_dropout_schedule guarantees this)")
    return (m / tot).astype(np.float32)


# --------------------------------------------------------------------------
# Robust aggregation statistics (hostile-world boundary, DESIGN.md §8)
# --------------------------------------------------------------------------

ROBUST_AGGREGATORS = ("median", "trimmed_mean", "krum")
AGGREGATORS = ("fedavg", "fedprox", "fedsgd") + ROBUST_AGGREGATORS

_MASK_BIG = 1e30        # sentinel pushed into masked-out sort slots; finite
                        # so downstream arithmetic never meets inf/nan


def _masked_sort(vals: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Sort (d, ...) along the silo axis with masked-out silos pushed to the
    top: valid entries occupy sorted positions [0, k) for k = Σ mask."""
    m = mask.reshape((-1,) + (1,) * (vals.ndim - 1))
    v = jnp.where(m > 0, vals.astype(jnp.float32), _MASK_BIG)
    return jnp.sort(v, axis=0)


def masked_median(vals: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Coordinate-wise median over silos with mask=1 (dropped / empty /
    padded silos excluded exactly). k may be a traced scalar."""
    s = _masked_sort(vals, mask)
    k = jnp.sum(mask).astype(jnp.int32)
    lo = jnp.maximum((k - 1) // 2, 0)
    hi = jnp.maximum(k // 2, 0)
    take = lambda i: lax.dynamic_index_in_dim(s, i, 0, keepdims=False)
    return 0.5 * (take(lo) + take(hi))


def masked_trimmed_mean(vals: jnp.ndarray, mask: jnp.ndarray,
                        trim_frac: float) -> jnp.ndarray:
    """Coordinate-wise mean over the valid silos with the floor(k·trim_frac)
    smallest AND largest values dropped per coordinate; the trim is clamped
    so at least one value survives."""
    d = vals.shape[0]
    s = _masked_sort(vals, mask)
    k = jnp.sum(mask).astype(jnp.int32)
    t = jnp.floor(k.astype(jnp.float32) * float(trim_frac)).astype(jnp.int32)
    t = jnp.clip(t, 0, jnp.maximum((k - 1) // 2, 0))
    idx = jnp.arange(d, dtype=jnp.int32)
    keep = ((idx >= t) & (idx < k - t)).astype(jnp.float32)
    kept = jnp.tensordot(keep, s, axes=(0, 0))
    return kept / jnp.maximum(k - 2 * t, 1).astype(jnp.float32)


def krum_select(flat: jnp.ndarray, mask: jnp.ndarray,
                krum_f: int) -> jnp.ndarray:
    """Krum selection index over (d, P) flattened silo updates: each valid
    silo is scored by the sum of its squared distances to its k−f−2 nearest
    valid peers; the lowest score wins (Blanchard et al., NeurIPS'17).
    Distances between params and between deltas coincide (the shared
    round-start offset cancels), so callers may pass either."""
    d = flat.shape[0]
    f32 = flat.astype(jnp.float32)
    sq = jnp.sum(f32 * f32, axis=1)
    dist = sq[:, None] + sq[None, :] - 2.0 * (f32 @ f32.T)
    valid = mask > 0
    pair = valid[:, None] & valid[None, :] & ~jnp.eye(d, dtype=bool)
    dist = jnp.where(pair, jnp.maximum(dist, 0.0), _MASK_BIG)
    k = jnp.sum(mask).astype(jnp.int32)
    nn = jnp.clip(k - int(krum_f) - 2, 1, jnp.maximum(k - 1, 1))
    sd = jnp.sort(dist, axis=1)
    neighbor = (jnp.arange(d, dtype=jnp.int32)[None, :] < nn)
    scores = jnp.sum(jnp.where(neighbor, sd, 0.0), axis=1)
    scores = jnp.where(valid, scores, jnp.inf)
    return jnp.argmin(scores)


def robust_aggregate(stacked: Any, mask: jnp.ndarray, aggregator: str, *,
                     trim_frac: float = 0.2, krum_f: int = 1) -> Any:
    """Robust boundary over a (d, ...) silo-stacked pytree: aggregate only
    the silos with mask=1 (available AND real), ignoring sample weights —
    the classical Byzantine-robust estimators are unweighted by design, so a
    poisoned silo cannot buy influence with a large claimed sample count."""
    if aggregator == "median":
        return jax.tree.map(
            lambda a: masked_median(a, mask).astype(a.dtype), stacked)
    if aggregator == "trimmed_mean":
        return jax.tree.map(
            lambda a: masked_trimmed_mean(a, mask, trim_frac).astype(a.dtype),
            stacked)
    if aggregator == "krum":
        leaves = jax.tree_util.tree_leaves(stacked)
        flat = jnp.concatenate(
            [l.reshape(l.shape[0], -1).astype(jnp.float32) for l in leaves],
            axis=1)
        best = krum_select(flat, mask, krum_f)
        return jax.tree.map(
            lambda a: lax.dynamic_index_in_dim(a, best, 0, keepdims=False),
            stacked)
    raise ValueError(f"unknown robust aggregator {aggregator!r}; "
                     f"choose one of {ROBUST_AGGREGATORS}")


def apply_silo_scale(stacked: Any, ref: Any, scale: jnp.ndarray) -> Any:
    """Per-silo delta scaling at the boundary: silo i submits
    ref + scale_i·(p_i − ref). The gradient-scaling attacker's injection
    point (core/privacy.py) — and an EXACT no-op at scale=1 (the update is
    written p + (scale−1)·(p − ref), so honest silos add literal 0.0)."""
    def leaf(s, g):
        sc = (scale.astype(jnp.float32) - 1.0).reshape(
            (-1,) + (1,) * (s.ndim - 1))
        delta = s.astype(jnp.float32) - g.astype(jnp.float32)[None]
        return (s.astype(jnp.float32) + sc * delta).astype(s.dtype)
    return jax.tree.map(leaf, stacked, ref)


def round_perms(key, rnd, num_silos: int, epochs: int, n_slots: int,
                silo_ids: Optional[jnp.ndarray] = None):
    """Minibatch schedule for one round: a (d, epochs, n_slots) permutation
    stack derived purely from (seed, round, silo, epoch) via fold_in — the
    same indices whether `rnd` is a concrete int (host loop) or a traced
    scan counter (scan engine). `silo_ids` overrides the silo indices folded
    into the key: a mesh shard holding silos [4..7] of a sharded plan passes
    its GLOBAL ids so its streams match the single-device engine exactly."""
    kr = jax.random.fold_in(key, rnd)
    ids = jnp.arange(num_silos) if silo_ids is None else silo_ids

    def silo(i):
        ki = jax.random.fold_in(kr, i)
        return jax.vmap(
            lambda e: jax.random.permutation(jax.random.fold_in(ki, e),
                                             n_slots))(jnp.arange(epochs))

    return jax.vmap(silo)(ids)


def _detect_per_example(loss_fn, params, padded: PaddedSilos) -> bool:
    """A loss returning shape (batch,) is per-example (maskable); shape ()
    is a black-box batch mean (legacy; valid only without padding)."""
    bs = padded.batch_size
    x_s = jax.ShapeDtypeStruct((bs,) + padded.X.shape[2:], padded.X.dtype)
    y_s = jax.ShapeDtypeStruct((bs,) + padded.Y.shape[2:], padded.Y.dtype)
    out = jax.eval_shape(loss_fn, params, x_s, y_s)
    if out.shape == ():
        return False
    if out.shape == (bs,):
        return True
    raise ValueError(
        f"loss_fn must return a scalar batch mean or a (batch,)-shaped "
        f"per-example vector; got shape {out.shape}")


def _make_batch_loss(loss_fn, per_example: bool, fedprox_mu: float):
    """Masked batch objective shared by every aggregator and engine.

    Per-example losses are weighted by the sample mask (padded slots
    contribute exactly zero to value and gradient); scalar losses are used
    verbatim (the caller guarantees no padding). FedProx adds the proximal
    pull toward the round-start global params."""
    def batch_loss(p, x, y, w, ref):
        if per_example:
            l = loss_fn(p, x, y)
            # tiny-eps denominator guard (see _DEN_EPS): identical to the
            # old max(Σw, 1) for {0,1} masks (mass 0 or ≥ 1), but no longer
            # deflates loss/gradient under fractional sample weights
            loss = jnp.sum(w * l) / jnp.maximum(jnp.sum(w), _DEN_EPS)
        else:
            loss = loss_fn(p, x, y)
        if fedprox_mu:
            loss = loss + fedprox_regularizer(p, ref, fedprox_mu)
        return loss

    return batch_loss


def _make_sgd_step(batch_loss, opt: Optimizer, masked: bool = False):
    """masked=True additionally suppresses the optimizer update for batches
    with ZERO real samples: without the guard an all-padding batch would
    still advance the step counter, decay momentum, and coast parameters on
    stale Adam state — so small ragged silos would take extra effective
    steps. With it, all-padding batches are exact no-ops and a silo's
    training is the sequence of its real-sample batches only."""
    def step(p, opt_state, x, y, w, ref):
        loss, grads = jax.value_and_grad(batch_loss)(p, x, y, w, ref)
        updates, new_state = opt.update(grads, opt_state, p)
        new_p = apply_updates(p, updates)
        if masked:
            has_real = jnp.sum(w) > 0
            new_p = jax.tree.map(
                lambda a, b: jnp.where(has_real, a, b), new_p, p)
            new_state = jax.tree.map(
                lambda a, b: jnp.where(has_real, a, b), new_state, opt_state)
        return new_p, new_state, loss

    return step


def _weighted_silo_mean(stacked: Any, wn: jnp.ndarray) -> Any:
    """Sample-weighted mean over the leading silo dim (wn sums to 1)."""
    return jax.tree.map(
        lambda a: jnp.tensordot(wn, a.astype(jnp.float32),
                                axes=(0, 0)).astype(a.dtype), stacked)


def _stack_trees(trees: Sequence[Any]) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


# ==========================================================================
# 1a. Mesh plumbing for sharded plans (DESIGN.md §7)
# ==========================================================================

def default_silo_axes(mesh) -> Tuple[str, ...]:
    """Mesh axes the padded silo dim shards over. When the mesh has both
    "pod" and "data" axes the silo dim spans them jointly and the round
    boundary aggregates hierarchically (intra-pod reduce over "data" first,
    cross-pod over "pod" second — the scarce-DCI comm structure of TFL,
    arXiv:1912.11187). A "model" axis is never a silo axis: model-parallel
    rows inside one silo group stay replicated w.r.t. the silo stack."""
    names = tuple(mesh.axis_names)
    both = tuple(a for a in ("pod", "data") if a in names)
    return both if both else names[:1]


def _mesh_axis_sizes(mesh) -> Dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def num_silo_shards(mesh, silo_axes: Optional[Sequence[str]] = None) -> int:
    """How many ways a sharded plan splits the silo axis (the padded silo
    count must be a multiple of this; run_federated pads it up)."""
    axes = tuple(silo_axes) if silo_axes else default_silo_axes(mesh)
    sizes = _mesh_axis_sizes(mesh)
    missing = [a for a in axes if a not in sizes]
    if missing:
        raise ValueError(f"silo axes {missing} not in mesh axes "
                         f"{tuple(mesh.axis_names)}")
    return int(np.prod([sizes[a] for a in axes]))


def _psum_tree(tree: Any, axes: Sequence[str]) -> Any:
    """Hierarchical all-reduce at the round boundary: innermost (intra-node)
    axis first, outer (cross-node) axes after. For axes=("pod", "data") that
    is one psum over "data" inside each pod, then one over "pod" across the
    DCI — exactly one weighted all-reduce per leaf per level, and the ONLY
    collectives a sharded plan with a WEIGHTED aggregator contains."""
    for ax in reversed(tuple(axes)):
        tree = jax.tree.map(lambda a: lax.psum(a, ax), tree)
    return tree


def _all_gather_tree(tree: Any, axes: Sequence[str]) -> Any:
    """Hierarchical tiled all-gather of the silo dim at a ROBUST round
    boundary (DESIGN.md §8): robust statistics are order statistics over the
    full cross-shard silo population, which a psum of partial sums cannot
    express — every shard must see every silo's submission. Same
    innermost-axis-first order as _psum_tree; after the gather each shard
    holds the full (d, …) stack and computes the identical robust aggregate
    redundantly (replicated output, no further collective)."""
    for ax in reversed(tuple(axes)):
        tree = jax.tree.map(
            lambda a: lax.all_gather(a, ax, axis=0, tiled=True), tree)
    return tree


# ==========================================================================
# 1b. The compiled-plan cache: shape-bucketed executable reuse
# ==========================================================================

def bucket_pow2(n: int) -> int:
    """Round n up to the next power of two (the default bucket policy):
    ≤ 2× padding waste, log-many buckets over any tenant population."""
    return 1 << (max(int(n), 1) - 1).bit_length()


def _tree_signature(tree: Any) -> Tuple:
    """Hashable (structure, leaf shapes/dtypes) fingerprint of a pytree."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return (str(treedef),
            tuple((tuple(np.shape(l)), str(jnp.asarray(l).dtype))
                  for l in leaves))


class PlanCache:
    """LRU cache of compiled FL plans keyed on the full compile signature.

    A plan (make_fl_plan) takes all tenant data as arguments, so two
    run_federated calls whose padded layouts land in the same shape bucket
    — (num_silos, num_batches, batch_size, feature/target shapes, params
    signature) — and share the same static config (aggregator, rounds,
    epochs, reset_opt, collect, per_example, fedprox_mu, loss/opt identity)
    reuse ONE jitted callable and therefore ONE XLA executable. Bucketing
    (bucket_silos / bucket_batches, default next-pow2) rounds the silo and
    batch axes UP so a new tenant's ragged shapes hit an existing
    executable instead of compiling a fresh one.

    Counters: hits / misses / evictions; a miss builds (and on first call
    compiles) a new plan, so `misses` == number of executables built
    through this cache.
    """

    def __init__(self, max_plans: int = 64,
                 bucket_silos: Callable[[int], int] = bucket_pow2,
                 bucket_batches: Callable[[int], int] = bucket_pow2):
        from collections import OrderedDict
        self._plans: "OrderedDict[Tuple, Tuple]" = OrderedDict()
        self.max_plans = max_plans
        self.bucket_silos = bucket_silos
        self.bucket_batches = bucket_batches
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._plans)

    def stats(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions, "plans": len(self._plans)}

    def clear(self) -> None:
        self._plans.clear()
        self.hits = self.misses = self.evictions = 0

    def lookup(self, key: Tuple, build: Callable[[], Callable],
               pins: Tuple = ()) -> Tuple[Callable, bool]:
        """Return (plan, was_hit). `pins` holds strong references (loss_fn,
        opt) for entries keyed on object identity, so a cached id() can
        never be recycled by the allocator while the entry lives."""
        if key in self._plans:
            self._plans.move_to_end(key)
            self.hits += 1
            return self._plans[key][0], True
        plan = build()
        self._plans[key] = (plan, pins)
        self.misses += 1
        while len(self._plans) > self.max_plans:
            self._plans.popitem(last=False)
            self.evictions += 1
        return plan, False


_DEFAULT_PLAN_CACHE: Optional[PlanCache] = None


def default_plan_cache() -> PlanCache:
    """The process-wide plan cache used by ``run_federated(cache=True)``
    and the FedDCL.fit() API."""
    global _DEFAULT_PLAN_CACHE
    if _DEFAULT_PLAN_CACHE is None:
        _DEFAULT_PLAN_CACHE = PlanCache()
    return _DEFAULT_PLAN_CACHE


def plan_cache_stats() -> Dict[str, int]:
    return default_plan_cache().stats()


def clear_plan_cache() -> None:
    if _DEFAULT_PLAN_CACHE is not None:
        _DEFAULT_PLAN_CACHE.clear()


# ==========================================================================
# 2. The unified federated engine
# ==========================================================================

@dataclass
class FLResult:
    params: Any
    history: List[Dict[str, float]]
    cache_stats: Optional[Dict[str, int]] = None   # set when a PlanCache ran


def fedavg_average(params_list: Sequence[Any], weights: Sequence[float]) -> Any:
    w = np.asarray(weights, np.float64)
    w = w / max(w.sum(), _DEN_EPS)
    return jax.tree.map(
        lambda *ps: sum(wi * p.astype(jnp.float32) for wi, p in zip(w, ps)).astype(ps[0].dtype),
        *params_list,
    )


def run_federated(
    loss_fn: Callable[[Any, jnp.ndarray, jnp.ndarray], jnp.ndarray],
    init_params: Any,
    silo_data: Sequence[Tuple[np.ndarray, np.ndarray]],
    *,
    opt: Optimizer,
    rounds: int,
    local_epochs: int,
    batch_size: int = 32,
    aggregator: str = "fedavg",
    fedprox_mu: float = 0.0,
    seed: int = 0,
    eval_fn: Optional[Callable[[Any], Dict[str, float]]] = None,
    engine: str = "host",
    per_example: Optional[bool] = None,
    reset_opt_per_round: bool = True,
    pad_fill: float = 0.0,
    cache: Any = None,
    loss_id: Optional[Tuple] = None,
    opt_id: Optional[Tuple] = None,
    mesh=None,
    silo_axes: Optional[Sequence[str]] = None,
    eval_chunk: int = 8,
    dropout_rate: float = 0.0,
    availability: Optional[np.ndarray] = None,
    silo_scale: Optional[Sequence[float]] = None,
    trim_frac: float = 0.2,
    krum_f: int = 1,
) -> FLResult:
    """Federated training over host-resident silo datasets — the ONE trainer
    behind FedAvg / FedProx / FedSGD / FedDCL and (via baselines.sgd_train)
    Centralized / Local / DC.

    loss_fn takes (params, x, y) and returns either a (batch,) per-example
    loss vector (preferred: ragged silos are zero-padded and masked) or a
    scalar batch mean (legacy; only valid when no padding is needed, i.e.
    every silo has the same size divisible by batch_size). `per_example` is
    auto-detected from the output shape when None.

    engine="host" is the paper-faithful per-batch-dispatch loop;
    engine="scan" compiles the whole schedule into one lax.scan program.
    Both use the same jax.random batch schedule and agree to float
    tolerance for the same seed.

    reset_opt_per_round=False carries silo optimizer state across rounds
    (used by sgd_train, where rounds are plain epochs).

    cache=True (or a PlanCache instance) routes the scan engine through the
    shape-bucketed compiled-plan cache (DESIGN.md §6): the padded layout is
    rounded UP to the cache's silo/batch buckets and the compiled
    executable is shared with every other call whose compile signature
    matches — a sweep's 2nd–Nth configs then cost milliseconds. Because
    bucketing changes n_slots (and so the minibatch schedule), the bucketed
    layout is the canonical layout of a cached run: two cached runs agree
    bitwise, and they agree with the host engine on the SAME bucketed
    layout to engine tolerance. loss_id / opt_id give the loss/optimizer a
    stable cache identity (e.g. ("mlp_per_example_loss", task) /
    ("adamw", lr)); when omitted, object identity is used, which only hits
    when the caller reuses the exact same callables. cache_stats on the
    result records {hit, hits, misses, evictions, plans}.

    mesh (scan engine only) runs the FL phase sharded: the padded silo
    stack is placed over the mesh's silo axes (silo_axes, default
    `default_silo_axes` — ("pod", "data") jointly when both exist) via
    shard_map, with hierarchical round-boundary psums as the ONLY
    collectives (DESIGN.md §7). The silo count is padded up to a multiple
    of the silo-shard count with empty no-op silos, so results match the
    unsharded engine to float tolerance. eval_chunk bounds the eval path's
    memory: with eval_fn, per-round params stream to host eval_chunk
    rounds per dispatch instead of materializing a (rounds, |params|)
    stack on device.

    HOSTILE-WORLD options (DESIGN.md §8): aggregator may also be one of
    `ROBUST_AGGREGATORS` — "median" / "trimmed_mean" (trim_frac per tail) /
    "krum" (krum_f tolerated Byzantine silos) compute an UNWEIGHTED robust
    statistic over the available silos' submissions instead of the
    sample-weighted mean (sharded: via a cross-silo all_gather instead of
    the psum). dropout_rate draws a per-(round, silo) Bernoulli availability
    schedule on host (`make_dropout_schedule`; `availability` passes an
    explicit (rounds, num_real_silos) {0,1} matrix instead); unavailable
    silos train nothing that round (exact no-op under the §4 mask rules)
    and carry zero aggregation weight. silo_scale (num_real_silos,)
    multiplies each silo's submitted round delta — the gradient-scaling
    attacker's injection point (core/privacy.py); 1.0 is an exact no-op.
    """
    if aggregator not in AGGREGATORS:
        raise ValueError(f"unknown aggregator {aggregator!r}; "
                         f"choose one of {AGGREGATORS}")
    if engine not in ("host", "scan"):
        raise ValueError(f"unknown engine {engine!r}; choose 'host' or 'scan'")
    if mesh is not None and engine != "scan":
        raise ValueError("mesh=... requires engine='scan' — the host engine "
                         "is a per-batch dispatch loop and cannot shard the "
                         "silo axis")
    plan_cache: Optional[PlanCache] = None
    if cache is not None and cache is not False:
        if engine != "scan":
            raise ValueError("cache=... requires engine='scan' — the plan "
                             "cache stores compiled scan-engine executables")
        plan_cache = cache if isinstance(cache, PlanCache) else default_plan_cache()
    axes: Optional[Tuple[str, ...]] = None
    shards = 1
    if mesh is not None:
        axes = tuple(silo_axes) if silo_axes else default_silo_axes(mesh)
        shards = num_silo_shards(mesh, axes)

    def shard_multiple(d: int) -> int:
        """Round a silo count up to the silo-shard count (extra silos are
        empty → exact no-ops under the mask rules)."""
        return -(-d // shards) * shards

    if plan_cache is not None:
        n_max = max(np.asarray(x).shape[0] for x, _ in silo_data)
        if aggregator == "fedsgd":
            bs_eff: Optional[int] = plan_cache.bucket_batches(n_max)
            min_nb = 1
        else:
            bs_eff = batch_size
            min_nb = plan_cache.bucket_batches(-(-n_max // batch_size))
        padded = pad_silo_data(
            silo_data, bs_eff, fill=pad_fill, min_batches=min_nb,
            min_silos=shard_multiple(plan_cache.bucket_silos(len(silo_data))))
    else:
        padded = pad_silo_data(
            silo_data, None if aggregator == "fedsgd" else batch_size,
            fill=pad_fill,
            min_silos=shard_multiple(len(silo_data)) if shards > 1 else 0)
    if per_example is None:
        per_example = _detect_per_example(loss_fn, init_params, padded)
    if not per_example and padded.has_padding:
        raise ValueError(
            f"silo sizes {padded.sizes.astype(int).tolist()} need padding to "
            f"{padded.n_slots} slots, which a scalar (batch-mean) loss cannot "
            "mask — pass a per-example loss (returning a (batch,) vector, "
            "e.g. models.mlp.mlp_per_example_loss) or equal-size silos "
            "divisible by batch_size")
    if availability is not None and dropout_rate:
        raise ValueError("pass either dropout_rate or an explicit "
                         "availability matrix, not both")
    av: Optional[np.ndarray] = None
    if availability is not None:
        av = np.asarray(availability, np.float32)
        if av.shape[0] != rounds or av.shape[1] > padded.num_silos:
            raise ValueError(
                f"availability must be (rounds, num_silos≤{padded.num_silos})"
                f" for rounds={rounds}; got {av.shape}")
        if av.shape[1] < padded.num_silos:
            # bucket-padding silos are empty → never available
            av = np.concatenate(
                [av, np.zeros((rounds, padded.num_silos - av.shape[1]),
                              np.float32)], axis=1)
    elif dropout_rate:
        # draw over the REAL silo count so the schedule is invariant to
        # bucket/shard padding (a d=6 tenant gets the same draws whether the
        # layout pads to 6, 8, or 16 silos), then zero-pad the columns
        d_real = len(silo_data)
        av = make_dropout_schedule(seed, rounds, d_real,
                                   float(dropout_rate),
                                   sizes=padded.sizes[:d_real])
        if padded.num_silos > d_real:
            av = np.concatenate(
                [av, np.zeros((rounds, padded.num_silos - d_real),
                              np.float32)], axis=1)
    scale_vec: Optional[np.ndarray] = None
    if silo_scale is not None:
        s = np.asarray(silo_scale, np.float32).reshape(-1)
        if s.shape[0] > padded.num_silos:
            raise ValueError(f"silo_scale has {s.shape[0]} entries for "
                             f"{padded.num_silos} silos")
        scale_vec = np.ones(padded.num_silos, np.float32)
        scale_vec[:s.shape[0]] = s
    # dropout makes whole rounds all-padding for the dropped silos, so the
    # exact-no-op step guard must be on even when the layout itself is dense
    needs_mask = padded.has_padding or (av is not None and not np.all(av > 0))
    robust = aggregator in ROBUST_AGGREGATORS
    mu = fedprox_mu if aggregator == "fedprox" else 0.0
    batch_loss = _make_batch_loss(loss_fn, per_example, mu)
    if plan_cache is not None:
        mode = "chunk" if eval_fn is not None else "none"
        # mesh descriptor: a sharded and an unsharded plan must never alias,
        # nor two plans on meshes of different shape/axis names/silo axes
        mesh_sig = None if mesh is None else (
            tuple(mesh.axis_names),
            tuple(int(s) for s in mesh.devices.shape), axes)
        key = (
            padded.num_silos, padded.num_batches, padded.batch_size,
            tuple(padded.X.shape[2:]), str(padded.X.dtype),
            tuple(padded.Y.shape[2:]), str(padded.Y.dtype),
            _tree_signature(init_params),
            # chunk plans step nr rounds per dispatch with rounds never
            # baked into the executable, so they are rounds-agnostic:
            # rounds=50 and rounds=200 share one cached plan
            aggregator, None if mode == "chunk" else rounds,
            local_epochs, bool(reset_opt_per_round),
            mode, bool(per_example), float(mu),
            # robust-config enters the EXECUTABLE (trim/f are trace-time
            # constants), so plans differing only there must never alias;
            # dropout/scale are runtime ARGUMENTS and stay out of the key
            (float(trim_frac), int(krum_f)) if robust else None,
            loss_id if loss_id is not None else ("id", id(loss_fn)),
            opt_id if opt_id is not None else ("id", id(opt)),
            mesh_sig,
        )
        plan, was_hit = plan_cache.lookup(
            key,
            lambda: make_fl_plan(
                num_silos=padded.num_silos, num_batches=padded.num_batches,
                batch_size=padded.batch_size, opt=opt, batch_loss=batch_loss,
                rounds=rounds, local_epochs=local_epochs,
                aggregator=aggregator, per_example=per_example,
                reset_opt=reset_opt_per_round, collect=mode,
                masked=True, mesh=mesh, silo_axes=axes,
                trim_frac=trim_frac, krum_f=krum_f),
            pins=(loss_fn, opt))
        res = _run_scan(batch_loss, init_params, padded, opt=opt,
                        rounds=rounds, local_epochs=local_epochs,
                        aggregator=aggregator, seed=seed, eval_fn=eval_fn,
                        per_example=per_example, reset_opt=reset_opt_per_round,
                        plan=plan, eval_chunk=eval_chunk,
                        availability=av, silo_scale=scale_vec)
        res.cache_stats = {"hit": was_hit, **plan_cache.stats()}
        return res
    if engine == "host":
        return _run_host(batch_loss, init_params, padded, opt=opt,
                         rounds=rounds, local_epochs=local_epochs,
                         aggregator=aggregator, seed=seed, eval_fn=eval_fn,
                         per_example=per_example,
                         reset_opt=reset_opt_per_round,
                         availability=av, silo_scale=scale_vec,
                         trim_frac=trim_frac, krum_f=krum_f,
                         masked=needs_mask)
    return _run_scan(batch_loss, init_params, padded, opt=opt, rounds=rounds,
                     local_epochs=local_epochs, aggregator=aggregator,
                     seed=seed, eval_fn=eval_fn, per_example=per_example,
                     reset_opt=reset_opt_per_round, mesh=mesh,
                     silo_axes=axes, eval_chunk=eval_chunk,
                     availability=av, silo_scale=scale_vec,
                     trim_frac=trim_frac, krum_f=krum_f, masked=needs_mask)


# --------------------------------------------------------------------------
# 2a. engine="host": NumPy-orchestrated reference (one dispatch per batch)
# --------------------------------------------------------------------------

def _run_host(batch_loss, init_params, padded: PaddedSilos, *, opt, rounds,
              local_epochs, aggregator, seed, eval_fn, per_example,
              reset_opt, availability=None, silo_scale=None,
              trim_frac: float = 0.2, krum_f: int = 1,
              masked: Optional[bool] = None) -> FLResult:
    d, nb, bs = padded.num_silos, padded.num_batches, padded.batch_size
    key = jax.random.PRNGKey(seed)
    if masked is None:
        masked = padded.has_padding
    step = jax.jit(_make_sgd_step(batch_loss, opt, masked=masked))
    grad_fn = jax.jit(jax.value_and_grad(batch_loss))
    X, Y, w = padded.X, padded.Y, padded.w
    robust = aggregator in ROBUST_AGGREGATORS
    wr = _round_weights(padded.sizes, availability, rounds)   # (rounds, d)
    scale = None if silo_scale is None else \
        jnp.asarray(np.asarray(silo_scale, np.float32))

    gp = init_params
    fedsgd_state = opt.init(gp) if aggregator == "fedsgd" else None
    opt_states: List[Any] = [opt.init(gp) for _ in range(d)] if not reset_opt else []
    history: List[Dict[str, float]] = []
    for rnd in range(rounds):
        wr_r = wr[rnd]
        if aggregator == "fedsgd":
            losses, grads = [], []
            for i in range(d):
                li, gi = grad_fn(gp, jnp.asarray(X[i]), jnp.asarray(Y[i]),
                                 jnp.asarray(w[i]), gp)
                losses.append(li)
                grads.append(gi)
            g = _stack_trees(grads)
            if scale is not None:
                g = jax.tree.map(
                    lambda a: (a.astype(jnp.float32) * scale.reshape(
                        (-1,) + (1,) * (a.ndim - 1))).astype(a.dtype), g)
            g = _weighted_silo_mean(g, jnp.asarray(wr_r))
            updates, fedsgd_state = opt.update(g, fedsgd_state, gp)
            gp = apply_updates(gp, updates)
            round_loss = float(jnp.sum(jnp.asarray(wr_r) * jnp.stack(losses)))
        else:
            perms = np.asarray(
                round_perms(key, rnd, d, local_epochs, padded.n_slots))
            locals_: List[Any] = []
            final_losses = np.zeros(d)
            for i in range(d):
                if wr_r[i] <= 0:
                    # dropped or empty silo (wr_r > 0 ⟺ real ∧ available):
                    # trains nothing this round — the scan engine reaches the
                    # same state via zeroed sample masks + the masked-step
                    # no-op guard
                    locals_.append(gp)
                    continue
                p = gp
                o = opt.init(p) if reset_opt else opt_states[i]
                for e in range(local_epochs):
                    idx = perms[i, e].reshape(nb, bs)
                    # keep per-batch losses on device; only the final-epoch
                    # weighted mean is pulled to host (ONE sync per silo per
                    # round, like the pre-engine loop)
                    ep_losses, ep_ws = [], []
                    for b in range(nb):
                        sl = idx[b]
                        p, o, loss = step(p, o, jnp.asarray(X[i][sl]),
                                          jnp.asarray(Y[i][sl]),
                                          jnp.asarray(w[i][sl]), gp)
                        if e == local_epochs - 1:
                            ep_losses.append(loss)
                            ep_ws.append(float(w[i][sl].sum())
                                         if per_example else float(bs))
                    if e == local_epochs - 1:
                        num = sum(l * bw for l, bw in zip(ep_losses, ep_ws))
                        final_losses[i] = float(num) / max(sum(ep_ws),
                                                           _DEN_EPS)
                locals_.append(p)
                if not reset_opt:
                    opt_states[i] = o
            sp = _stack_trees(locals_)
            if scale is not None:
                sp = apply_silo_scale(sp, gp, scale)
            if robust:
                mask = jnp.asarray((wr_r > 0).astype(np.float32))
                gp = robust_aggregate(sp, mask, aggregator,
                                      trim_frac=trim_frac, krum_f=krum_f)
            else:
                gp = _weighted_silo_mean(sp, jnp.asarray(wr_r))
            round_loss = float(np.sum(np.float64(wr_r) * final_losses))
        rec = {"round": rnd, "loss": round_loss}
        if eval_fn is not None:
            rec.update(eval_fn(gp))
        history.append(rec)
    return FLResult(params=gp, history=history)


# --------------------------------------------------------------------------
# 2b. engine="scan": the whole FL phase as one compiled program
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class StreamedPlan:
    """Chunked bounded-memory form of a compiled FL plan (collect="chunk").

    ``step(carry, X, Y, w, wr_chunk, scale, key, rnd0, nr)`` advances ``nr``
    rounds (static) starting at round ``rnd0`` (traced; ``wr_chunk`` is the
    matching (nr, d) slice of the per-round weights) and returns
    ``(carry, (losses, params_per_round))`` where the stacked params have
    leading dim ``nr`` — the CHUNK size, never the total rounds. The eval
    path's peak extra memory is chunk × |params| instead of the old
    rounds × |params| stack, and because total rounds never enters the
    compiled program, one chunk executable serves every round budget.
    ``carry_init(init_params)`` builds the opaque cross-chunk training
    state (a donation-safe private copy on accelerators — ``step`` donates
    its carry so chunks recycle buffers); ``carry_params(carry)`` reads the
    current global params out of it."""
    step: Callable
    carry_init: Callable
    carry_params: Callable


def _resolve_collect(collect, collect_params) -> str:
    mode = collect if collect is not None else \
        ("stack" if collect_params else "none")
    if mode not in ("none", "stack", "chunk"):
        raise ValueError(f"unknown collect mode {mode!r}; "
                         "choose 'none', 'stack', or 'chunk'")
    return mode


def make_fl_plan(*, num_silos: int, num_batches: int, batch_size: int,
                 opt: Optimizer, batch_loss, rounds: int, local_epochs: int,
                 aggregator: str = "fedavg", per_example: bool = True,
                 reset_opt: bool = True, collect_params: bool = False,
                 masked: bool = True, collect: Optional[str] = None,
                 mesh=None, silo_axes: Optional[Sequence[str]] = None,
                 trim_frac: float = 0.2, krum_f: int = 1):
    """Build a compiled whole-FL-phase PLAN: a jitted

        ``plan(init_params, X, Y, w, wr, scale, key) -> (final_params, ys)``

    where X (d, n_slots, …), Y, w are the padded silo stack, wr (rounds, d)
    the PER-ROUND normalized aggregation weights (``_round_weights`` —
    every row equals ``_norm_weights(sizes)`` when no silo drops out; a
    zero entry marks a silo unavailable that round and suppresses its local
    training entirely), scale (d,) the per-silo delta multiplier
    (``apply_silo_scale``; all-ones in honest runs, the attack injection
    point otherwise), key the PRNG key that seeds the batch schedule, and
    ys the (rounds,) loss vector. Unlike a data-closure runner, ALL tenant
    data enters as arguments, so one plan compiles ONE executable per
    input-shape set and every tenant whose padded shapes land in the same
    bucket reuses it — the unit the PlanCache stores. Because wr and scale
    are arguments too, every dropout pattern and every attack configuration
    shares the same executable.

    aggregator ∈ ROBUST_AGGREGATORS swaps the round boundary from the
    weighted mean to a robust statistic over the available silos
    (trim_frac / krum_f are its trace-time constants — part of the plan's
    cache identity). Sharded robust plans all_gather the silo submissions
    instead of psumming partial weighted sums (DESIGN.md §8).

    collect (back-compat bool ``collect_params`` maps onto it):
      "none"  — ys is the (rounds,) loss vector (default).
      "stack" — ys is (losses, per-round params stacked (rounds, |params|)).
                LEGACY: materializes the full stack on device; kept for the
                streamed-vs-stacked regression tests only.
      "chunk" — returns a StreamedPlan whose step scans a CHUNK of rounds
                and emits only that chunk's params — the bounded-memory
                eval path (_run_scan streams chunks to host and keeps only
                scalar metrics).

    mesh/silo_axes (DESIGN.md §7): with a mesh, the whole FL phase runs
    under shard_map with the padded silo dim sharded over silo_axes
    (default ``default_silo_axes``: ("pod", "data") jointly when both
    exist), params/PRNG replicated, and the entire local phase
    collective-free per shard — each shard trains its d/shards silos with
    their GLOBAL silo ids folded into the batch schedule, so the results
    match the single-device plan to float tolerance. The only collectives
    are the round-boundary weighted psums of fedavg_sync (one per leaf per
    silo-axis level, hierarchical: intra-pod first, cross-pod second).
    num_silos must be divisible by the silo-shard count (run_federated pads
    with empty no-op silos)."""
    d, nb, bs = num_silos, num_batches, batch_size
    n_slots = nb * bs
    mode = _resolve_collect(collect, collect_params)
    axes: Optional[Tuple[str, ...]] = None
    if mesh is not None:
        axes = tuple(silo_axes) if silo_axes else default_silo_axes(mesh)
        shards = num_silo_shards(mesh, axes)
        if d % shards:
            raise ValueError(
                f"num_silos={d} is not divisible by the {shards}-way silo "
                f"mesh {axes}; pad the silo stack (pad_silo_data min_silos, "
                "as run_federated does) so every shard holds d/shards silos")
    step = _make_sgd_step(batch_loss, opt, masked=masked)
    vstep = jax.vmap(step, in_axes=(0, 0, 0, 0, 0, None))
    gather = jax.vmap(lambda a, i: a[i])                 # (d, n_slots, …) × (d, B)

    def make_schedule(key, rnds):
        """Batch schedule for the given rounds, (r, d, E, n_slots) over ALL
        d silos. Sharded plans compute this OUTSIDE the shard_map region and
        pass it in sharded over the silo dim — each shard then scans its own
        silos' GLOBAL streams. Two reasons: it keeps the shard-local program
        free of jax.random entirely, and it works around a jax 0.4.x
        miscompile where the sort inside jax.random.permutation, lowered
        within a shard_map manual region and consumed by a lax.scan, is
        rewritten with partition-id so every shard silently gets shard 0's
        permutations (verified on CPU; tests/test_fed_sharded.py would catch
        it as a ~1e-2 disagreement)."""
        return jax.vmap(
            lambda r: round_perms(key, r, d, local_epochs, n_slots))(rnds)

    def reduce_tree(stacked: Any, wn) -> Any:
        """fedavg_sync in plan form: the weighted mean over the GLOBAL silo
        axis — a local f32 tensordot over this shard's silos plus (when
        sharded) the hierarchical round-boundary psum; wn sums to 1 over
        all d silos, so the psum of partial weighted sums IS the mean."""
        part = jax.tree.map(
            lambda a: jnp.tensordot(wn, a.astype(jnp.float32), axes=(0, 0)),
            stacked)
        if axes is not None:
            part = _psum_tree(part, axes)
        return jax.tree.map(lambda p, s: p.astype(s.dtype), part, stacked)

    def reduce_sum(x):
        return _psum_tree(x, axes) if axes is not None else x

    def local_phase(gp, so, perms, X, Y, w):
        """E epochs × nb batches of vmapped silo steps over this shard's
        silos (perms: this shard's (dl, E, n_slots) schedule slice); returns
        trained silo params/opt state and per-silo final-epoch loss.
        Contains NO collective and NO PRNG: everything is vmapped over the
        local silo dim with per-silo masks."""
        dl = perms.shape[0]
        bidx = perms.reshape(dl, local_epochs, nb, bs).transpose(1, 2, 0, 3)

        def epoch_body(c, eb):                            # eb: (nb, dl, bs)
            def batch_body(c2, ib):                       # ib: (dl, bs)
                sp2, so2 = c2
                xb, yb, wb = gather(X, ib), gather(Y, ib), gather(w, ib)
                sp2, so2, losses = vstep(sp2, so2, xb, yb, wb, gp)
                bw = jnp.sum(wb, axis=1) if per_example \
                    else jnp.full((dl,), float(bs))
                return (sp2, so2), (losses * bw, bw)

            c, (ls, ws) = lax.scan(batch_body, c, eb)
            # tiny-eps guard (_DEN_EPS): identical for {0,1} masks, no
            # silent deflation when an epoch's real weight mass is < 1
            ep_loss = jnp.sum(ls, 0) / jnp.maximum(jnp.sum(ws, 0), _DEN_EPS)
            return c, ep_loss

        (sp, so), ep_losses = lax.scan(
            epoch_body, (silo_replicate(gp, dl), so), bidx)
        return sp, so, ep_losses[-1]                      # (dl,)

    robust = aggregator in ROBUST_AGGREGATORS

    def boundary(sp, gp, wr_r, scale):
        """Round-boundary sync of this shard's trained silo params sp:
        apply the per-silo delta scaling (attack injection; exact no-op at
        scale=1), then either the weighted mean (one psum per leaf per
        level when sharded) or — for robust aggregators — a cross-silo
        all_gather followed by the masked robust statistic, computed
        redundantly per shard on identical gathered inputs (replicated
        output, no further collective; the §7 sort-in-shard_map miscompile
        concern does not bite here because every shard sorts the SAME
        gathered array)."""
        sp = apply_silo_scale(sp, gp, scale)
        if not robust:
            return reduce_tree(sp, wr_r)
        avail = (wr_r > 0).astype(jnp.float32)
        if axes is not None:
            sp, avail = _all_gather_tree((sp, avail), axes)
        return robust_aggregate(sp, avail, aggregator,
                                trim_frac=trim_frac, krum_f=krum_f)

    def round_step(carry, perms, X, Y, w, wr_r, scale):
        """One full round on this shard's silo slice (perms: this round's
        (dl, E, n_slots) schedule; wr_r: this round's (dl,) weight row —
        zero entries are silos unavailable this round, whose sample masks
        are zeroed so local training is an exact no-op). Returns
        (carry, round_loss, global_params)."""
        if aggregator == "fedsgd":
            gp, fs = carry
            losses, grads = jax.vmap(
                lambda x, y, wi: jax.value_and_grad(batch_loss)(gp, x, y,
                                                                wi, gp)
            )(X, Y, w)
            grads = jax.tree.map(
                lambda a: (a.astype(jnp.float32) * scale.reshape(
                    (-1,) + (1,) * (a.ndim - 1))).astype(a.dtype), grads)
            g = reduce_tree(grads, wr_r)
            updates, fs = opt.update(g, fs, gp)
            gp = apply_updates(gp, updates)
            return (gp, fs), reduce_sum(jnp.sum(wr_r * losses)), gp
        # availability suppression: w·1.0 is bit-exact for present silos,
        # absent silos get all-zero masks → every batch is an exact no-op
        # under the masked-step guard (run_federated forces masked=True
        # whenever any wr entry is zero)
        w_eff = w * (wr_r > 0).astype(w.dtype)[:, None]
        if reset_opt:
            gp = carry
            so = jax.vmap(opt.init)(silo_replicate(gp, X.shape[0]))
            sp, _, final_losses = local_phase(gp, so, perms, X, Y, w_eff)
            gp = boundary(sp, gp, wr_r, scale)
            return gp, reduce_sum(jnp.sum(wr_r * final_losses)), gp
        gp, so = carry
        sp, so, final_losses = local_phase(gp, so, perms, X, Y, w_eff)
        gp = boundary(sp, gp, wr_r, scale)
        return (gp, so), reduce_sum(jnp.sum(wr_r * final_losses)), gp

    own_state = aggregator == "fedsgd" or not reset_opt

    def carry_init_traced(gp, dl):
        if aggregator == "fedsgd":
            return (gp, opt.init(gp))
        if reset_opt:
            return gp
        return (gp, jax.vmap(opt.init)(silo_replicate(gp, dl)))

    def carry_params(carry):
        return carry[0] if own_state else carry

    def data_specs(X, Y, w):
        """silo-axis sharding for the padded tenant stacks: leading dim over
        the (possibly hierarchical) silo axes, everything else shard-local
        (shardingx.policy.batch_spec, federated tuple form). The last two
        entries cover wr (rounds, d — rounds replicated, silo dim sharded)
        and scale (d,)."""
        return (batch_spec(mesh, federated=True, silo_axis=axes, ndim=X.ndim),
                batch_spec(mesh, federated=True, silo_axis=axes, ndim=Y.ndim),
                batch_spec(mesh, federated=True, silo_axis=axes, ndim=w.ndim),
                P(None, axes), P(axes))

    def carry_specs(carry):
        rep = lambda t: jax.tree.map(lambda _: P(), t)
        if aggregator == "fedsgd":
            return (rep(carry[0]), rep(carry[1]))
        if reset_opt:
            return rep(carry)
        silo = jax.tree.map(
            lambda l: P(axes, *([None] * (l.ndim - 1))), carry[1])
        return (rep(carry[0]), silo)

    def round_body_of(key, emit, X, Y, w, scale):
        """Scan body over (sched, wr) xs: sched is either this round's
        (dl, E, n_slots) schedule slice (sharded — the PRNG ran outside the
        manual region, see make_schedule) or the scalar round index
        (unsharded / fedsgd — the schedule is derived in-scan exactly as
        before); wr_r is this round's (dl,) aggregation-weight row."""
        def round_body(c, x):
            sx, wr_r = x
            if aggregator == "fedsgd":
                pr = None
            elif sx.ndim == 0:
                pr = round_perms(key, sx, d, local_epochs, n_slots)
            else:
                pr = sx
            c, rl, gp = round_step(c, pr, X, Y, w, wr_r, scale)
            return c, emit(rl, gp)
        return round_body

    def sched_for(key, rnds):
        if axes is None or aggregator == "fedsgd":
            return rnds, P()
        return make_schedule(key, rnds), P(None, axes)

    if mode in ("none", "stack"):
        emit = (lambda rl, gp: (rl, gp)) if mode == "stack" \
            else (lambda rl, gp: rl)

        @jax.jit
        def plan(init_params, X, Y, w, wr, scale, key):
            def whole(init_params, X, Y, w, wr, scale, key, sched):
                carry0 = carry_init_traced(init_params, X.shape[0])
                c, ys = lax.scan(round_body_of(key, emit, X, Y, w, scale),
                                 carry0, (sched, wr))
                return carry_params(c), ys

            sched, sspec = sched_for(key, jnp.arange(rounds))
            if axes is None:
                return whole(init_params, X, Y, w, wr, scale, key, sched)
            sx, sy, sw, swr, ssc = data_specs(X, Y, w)
            return shard_map(whole, mesh,
                             in_specs=(P(), sx, sy, sw, swr, ssc, P(),
                                       sspec),
                             out_specs=P(), check_rep=False)(
                init_params, X, Y, w, wr, scale, key, sched)

        return plan

    # mode == "chunk": the bounded-memory streamed plan; wr arrives as this
    # chunk's (nr, d) ROW SLICE (the driver slices wr[rnd0:rnd0+nr]) so
    # total rounds still never enters the executable
    def chunk_step(carry, X, Y, w, wr, scale, key, rnd0, nr):
        emit = lambda rl, gp: (rl, gp)

        def whole(carry, X, Y, w, wr, scale, key, sched):
            return lax.scan(round_body_of(key, emit, X, Y, w, scale),
                            carry, (sched, wr))

        sched, sspec = sched_for(key, rnd0 + jnp.arange(nr))
        if axes is None:
            return whole(carry, X, Y, w, wr, scale, key, sched)
        sx, sy, sw, swr, ssc = data_specs(X, Y, w)
        cs = carry_specs(carry)
        return shard_map(whole, mesh,
                         in_specs=(cs, sx, sy, sw, swr, ssc, P(), sspec),
                         out_specs=(cs, P()), check_rep=False)(
            carry, X, Y, w, wr, scale, key, sched)

    # CPU has no buffer donation; elsewhere chunks recycle carry buffers
    donate = () if jax.default_backend() == "cpu" else (0,)
    jitted_step = jax.jit(chunk_step, static_argnums=(8,),
                          donate_argnums=donate)

    def carry_init(init_params):
        # private copy so donation can never invalidate the caller's params
        gp = jax.tree.map(jnp.array, init_params)
        if aggregator == "fedsgd":
            return (gp, opt.init(gp))
        if reset_opt:
            return gp
        return (gp, jax.vmap(opt.init)(silo_replicate(gp, d)))

    return StreamedPlan(step=jitted_step, carry_init=carry_init,
                        carry_params=carry_params)


def _plan_args(padded: PaddedSilos, seed: int, rounds: int, *,
               availability: Optional[np.ndarray] = None,
               silo_scale: Optional[np.ndarray] = None):
    """Device arguments a plan consumes for one tenant's padded stack:
    (X, Y, w, wr, scale, key). availability (rounds, d) {0,1} folds into
    the per-round weights wr; silo_scale (d,) defaults to all-ones
    (honest)."""
    wr = _round_weights(padded.sizes, availability, rounds)
    scale = (np.ones(padded.num_silos, np.float32) if silo_scale is None
             else np.asarray(silo_scale, np.float32))
    return (jnp.asarray(padded.X), jnp.asarray(padded.Y),
            jnp.asarray(padded.w), jnp.asarray(wr), jnp.asarray(scale),
            jax.random.PRNGKey(seed))


def lower_fl_plan(plan, init_params, padded: PaddedSilos, *, rounds: int,
                  seed: int = 0, availability: Optional[np.ndarray] = None,
                  silo_scale: Optional[np.ndarray] = None,
                  eval_chunk: int = 8):
    """Lower a `make_fl_plan` plan over a tenant's padded stack WITHOUT
    executing it — the hook the artifact auditor drives
    (`repro.analysis.hlo_audit`): `collective_census(lowered)` checks the
    round-boundary communication structure and `assert_no_baked_data`
    checks that no tenant array was baked into the trace as a constant.
    Works for both plan forms: a plain jitted plan lowers over the full
    argument tuple; a `StreamedPlan` lowers its chunk step (one
    min(eval_chunk, rounds)-round dispatch, the unit that actually
    compiles)."""
    args = _plan_args(padded, seed, rounds, availability=availability,
                      silo_scale=silo_scale)
    if isinstance(plan, StreamedPlan):
        X, Y, w, wr, scale, key = args
        nr = min(int(eval_chunk), int(rounds))
        carry = plan.carry_init(init_params)
        return plan.step.lower(carry, X, Y, w, wr[:nr], scale, key,
                               jnp.int32(0), nr)
    return plan.lower(init_params, *args)


def make_scan_runner(batch_loss, padded: PaddedSilos, *, opt, rounds,
                     local_epochs, aggregator="fedavg", seed=0,
                     per_example=True, reset_opt=True,
                     collect_params=False, mesh=None,
                     silo_axes=None, availability=None, silo_scale=None,
                     trim_frac: float = 0.2, krum_f: int = 1) -> Callable:
    """Back-compat data-closure wrapper over make_fl_plan: a
    ``run(init_params) -> (final_params, ys)`` with this tenant's padded
    stack bound. Calling the SAME runner twice reuses the compiled
    executable — what benchmarks/fed_bench.py times as the warm FL phase.
    With mesh, the plan runs sharded (the padded silo count must already be
    a multiple of the silo-shard count)."""
    dropout = availability is not None and not np.all(
        np.asarray(availability) > 0)
    plan = make_fl_plan(
        num_silos=padded.num_silos, num_batches=padded.num_batches,
        batch_size=padded.batch_size, opt=opt, batch_loss=batch_loss,
        rounds=rounds, local_epochs=local_epochs, aggregator=aggregator,
        per_example=per_example, reset_opt=reset_opt,
        collect_params=collect_params,
        masked=padded.has_padding or dropout,
        mesh=mesh, silo_axes=silo_axes, trim_frac=trim_frac, krum_f=krum_f)
    args = _plan_args(padded, seed, rounds, availability=availability,
                      silo_scale=silo_scale)
    return lambda init_params: plan(init_params, *args)


def _run_scan(batch_loss, init_params, padded: PaddedSilos, *, opt, rounds,
              local_epochs, aggregator, seed, eval_fn, per_example,
              reset_opt, plan=None, mesh=None, silo_axes=None,
              eval_chunk: int = 8, availability=None, silo_scale=None,
              trim_frac: float = 0.2, krum_f: int = 1,
              masked: Optional[bool] = None) -> FLResult:
    """Drive a compiled plan over this tenant's padded stack.

    With eval_fn, the plan is a StreamedPlan: the FL phase runs in
    eval_chunk-round dispatches that each emit only that chunk's per-round
    params, which are fetched to host ONCE per chunk (one device_get for
    the whole chunk tree, not one transfer per leaf per round), evaluated,
    and dropped — peak extra memory is eval_chunk × |params| regardless of
    rounds. Without eval_fn, one dispatch runs the whole phase and only
    the (rounds,) loss vector comes back."""
    if masked is None:
        masked = padded.has_padding or (
            availability is not None and not np.all(
                np.asarray(availability) > 0))
    if plan is None:
        mode = "chunk" if eval_fn is not None else "none"
        plan = make_fl_plan(
            num_silos=padded.num_silos, num_batches=padded.num_batches,
            batch_size=padded.batch_size, opt=opt, batch_loss=batch_loss,
            rounds=rounds, local_epochs=local_epochs, aggregator=aggregator,
            per_example=per_example, reset_opt=reset_opt, collect=mode,
            masked=masked, mesh=mesh, silo_axes=silo_axes,
            trim_frac=trim_frac, krum_f=krum_f)
    args = _plan_args(padded, seed, rounds, availability=availability,
                      silo_scale=silo_scale)

    if isinstance(plan, StreamedPlan):
        X, Y, w, wr, scale, key = args
        carry = plan.carry_init(init_params)
        history: List[Dict[str, float]] = []
        rnd0 = 0
        while rnd0 < rounds:
            nr = min(eval_chunk, rounds - rnd0)
            carry, (ls, ps) = plan.step(carry, X, Y, w, wr[rnd0:rnd0 + nr],
                                        scale, key, jnp.int32(rnd0), nr)
            host_ls = np.asarray(ls)
            # feddcl-lint: disable=R008  one transfer per eval_chunk rounds (the batched form the rule asks for), not one per round
            host_ps = jax.device_get(ps)
            for j in range(nr):
                rec = {"round": rnd0 + j, "loss": float(host_ls[j])}
                if eval_fn is not None:
                    rec.update(eval_fn(
                        jax.tree.map(lambda a: a[j], host_ps)))
                history.append(rec)
            rnd0 += nr
        return FLResult(params=plan.carry_params(carry), history=history)

    gp, ys = plan(init_params, *args)
    if eval_fn is not None:
        round_losses, round_params = ys
        round_losses = np.asarray(round_losses)
        # one host fetch for the whole (rounds, |params|) stack — the old
        # per-round tree.map(a[rnd]) forced a device round-trip per leaf
        # per round (ISSUE 7 satellite); the stacked mode itself remains
        # the legacy memory-heavy path kept for regression tests.
        host_params = jax.device_get(round_params)
        history = []
        for rnd in range(rounds):
            rec = {"round": rnd, "loss": float(round_losses[rnd])}
            rec.update(eval_fn(jax.tree.map(lambda a: a[rnd], host_params)))
            history.append(rec)
    else:
        round_losses = np.asarray(ys)
        history = [{"round": rnd, "loss": float(round_losses[rnd])}
                   for rnd in range(rounds)]
    return FLResult(params=gp, history=history)


# ==========================================================================
# 3. Mesh-level federated collectives (production / dry-run form)
# ==========================================================================

def silo_replicate(params: Any, num_silos: int) -> Any:
    """Give every leaf a leading silo dim (identical start, paper Step 4)."""
    return jax.tree.map(
        lambda p: jnp.broadcast_to(p[None], (num_silos,) + p.shape), params)


def silo_vmap_step(step_fn: Callable) -> Callable:
    """vmap a per-silo (params, opt_state, batch) -> (params, opt_state,
    metrics) step over the leading silo dim. The resulting HLO contains no
    collective over the silo mesh axis — verified by tests/test_federated.py.
    """
    return jax.vmap(step_fn, in_axes=0, out_axes=0)


def scan_local_steps(local_step: Callable, silo_params: Any,
                     silo_opt_state: Any, batches: Any):
    """Run H silo-local steps as ONE lax.scan — the launch-tier form of the
    scan engine's inner loop. `batches` is a pytree with leading dim H (then
    the per-step silo batch layout); returns (params, opt_state, metrics)
    with metrics stacked over H."""
    def body(c, b):
        sp, so = c
        sp, so, m = local_step(sp, so, b)
        return (sp, so), m

    (sp, so), ms = lax.scan(body, (silo_params, silo_opt_state), batches)
    return sp, so, ms


def fedavg_sync(silo_params: Any, weights: Optional[jnp.ndarray] = None) -> Any:
    """Round boundary: average parameters across the silo dim and broadcast
    back. Under GSPMD with the silo dim sharded over the silo mesh axis this
    lowers to exactly one all-reduce over that axis per leaf."""
    def avg(p):
        pf = p.astype(jnp.float32)
        if weights is None:
            mean = jnp.mean(pf, axis=0, keepdims=True)
        else:
            w = (weights /
                 jnp.maximum(jnp.sum(weights), _DEN_EPS)).astype(jnp.float32)
            mean = jnp.tensordot(w, pf, axes=(0, 0))[None]
        return jnp.broadcast_to(mean, p.shape).astype(p.dtype)

    return jax.tree.map(avg, silo_params)


def robust_sync(silo_params: Any, aggregator: str,
                mask: Optional[jnp.ndarray] = None, *,
                trim_frac: float = 0.2, krum_f: int = 1) -> Any:
    """Robust round boundary in fedavg_sync's broadcast-back form: compute
    the masked robust statistic over the silo dim and broadcast it back so
    every silo restarts the next round from the same point. aggregator may
    also be a weighted one ("fedavg"/"fedprox"/"fedsgd"), which falls back
    to fedavg_sync — launch/steps.py routes every configured aggregator
    through this one entry point."""
    if aggregator not in ROBUST_AGGREGATORS:
        return fedavg_sync(silo_params)
    d = jax.tree_util.tree_leaves(silo_params)[0].shape[0]
    m = jnp.ones((d,), jnp.float32) if mask is None else \
        mask.astype(jnp.float32)
    agg = robust_aggregate(silo_params, m, aggregator,
                           trim_frac=trim_frac, krum_f=krum_f)
    return jax.tree.map(
        lambda a, p: jnp.broadcast_to(a[None], p.shape).astype(p.dtype),
        agg, silo_params)


def fedprox_regularizer(params: Any, ref_params: Any, mu: float) -> jnp.ndarray:
    return 0.5 * mu * sum(
        jnp.sum(jnp.square(a.astype(jnp.float32) - b.astype(jnp.float32)))
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(ref_params)))
