"""Step 1 of FedDCL: construction of the shareable pseudo anchor dataset A.

All users must generate the SAME anchor, so every constructor is a pure
function of a shared seed (and, for the data-informed variants, of public
statistics that the institutions agree to share).

Three constructors per the paper §3.2:
  uniform  — uniform random within per-feature value ranges (the paper's
             experimental choice, after [8, 11])
  lowrank  — low-rank-approximation-based ([5]): anchor sampled from the
             span of the top right singular vectors of a public sample
  smote    — SMOTE-based ([6]): convex combinations of nearest public
             sample pairs
"""
from __future__ import annotations

from typing import Optional

import numpy as np


def uniform_anchor(seed: int, r: int, feat_min: np.ndarray,
                   feat_max: np.ndarray) -> np.ndarray:
    """Uniform random anchor inside the shared per-feature ranges."""
    rng = np.random.default_rng(seed)
    m = feat_min.shape[0]
    u = rng.uniform(size=(r, m))
    return feat_min[None, :] + u * (feat_max - feat_min)[None, :]


def lowrank_anchor(seed: int, r: int, public_sample: np.ndarray,
                   rank: Optional[int] = None) -> np.ndarray:
    """Anchor with the low-rank structure of a public sample [5]:
    A = mu + G (s_p ⊙ V_p)ᵀ with G standard normal."""
    rng = np.random.default_rng(seed)
    mu = public_sample.mean(axis=0)
    Xc = public_sample - mu
    U, s, Vt = np.linalg.svd(Xc, full_matrices=False)
    p = rank or max(1, min(Xc.shape) // 2)
    G = rng.standard_normal((r, p)) / np.sqrt(max(Xc.shape[0] - 1, 1))
    return mu[None, :] + G @ (s[:p, None] * Vt[:p])


def smote_anchor(seed: int, r: int, public_sample: np.ndarray,
                 k: int = 5) -> np.ndarray:
    """SMOTE-style anchor [6]: interpolate random points toward one of their
    k nearest neighbours."""
    rng = np.random.default_rng(seed)
    n = public_sample.shape[0]
    idx = rng.integers(0, n, size=r)
    base = public_sample[idx]
    # k nearest neighbours of each base point (O(r·n) — fine at anchor scale)
    d2 = ((base[:, None, :] - public_sample[None, :, :]) ** 2).sum(-1)
    d2[np.arange(r), idx] = np.inf
    nn = np.argpartition(d2, kth=min(k, n - 1) - 1, axis=1)[:, :k]
    pick = nn[np.arange(r), rng.integers(0, min(k, n - 1), size=r)]
    lam = rng.uniform(size=(r, 1))
    return base + lam * (public_sample[pick] - base)


def make_anchor(kind: str, seed: int, r: int, *, feat_min=None, feat_max=None,
                public_sample=None, rank=None) -> np.ndarray:
    if kind == "uniform":
        return uniform_anchor(seed, r, np.asarray(feat_min), np.asarray(feat_max))
    if kind == "lowrank":
        return lowrank_anchor(seed, r, np.asarray(public_sample), rank)
    if kind == "smote":
        return smote_anchor(seed, r, np.asarray(public_sample))
    raise ValueError(f"unknown anchor kind {kind!r}")
