"""The paper's comparison methods (§4.1): Centralized, Local, FedAvg, DC.

Each driver trains the same MLP family (models/mlp.py) with the substrate
optimizer, so differences between methods reflect the protocol, not the
trainer. FedAvg reuses core/federated.run_federated directly on raw silo
data; DC is the conventional single-central-server data collaboration
(all users' anchors to ONE server, one SVD, centralized training on X̂).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core import collab
from repro.core.anchor import make_anchor
from repro.core.federated import run_federated
from repro.core.mappings import fit_mapping
from repro.optim import Optimizer


def sgd_train(loss_fn, params, X, Y, *, opt: Optimizer, epochs: int,
              batch_size: int = 32, seed: int = 0,
              eval_fn: Optional[Callable] = None,
              engine: str = "host",
              per_example: Optional[bool] = None,
              cache=None, loss_id=None, opt_id=None) -> Tuple[dict, List[Dict]]:
    """Plain minibatch training used by Centralized / Local / DC — the d=1
    degenerate case of the federated engine: one silo, each "round" is one
    epoch, optimizer state carried across rounds, FedAvg over one silo is
    the identity. engine="scan" compiles the whole run into one dispatch;
    cache/loss_id/opt_id route it through the shared compiled-plan cache
    (core/federated.py) exactly like the federated methods."""
    res = run_federated(
        loss_fn, params, [(np.asarray(X), np.asarray(Y))], opt=opt,
        rounds=epochs, local_epochs=1, batch_size=batch_size, seed=seed,
        eval_fn=eval_fn, engine=engine, per_example=per_example,
        reset_opt_per_round=False, cache=cache, loss_id=loss_id,
        opt_id=opt_id)
    history = [{"epoch": h["round"],
                **{k: v for k, v in h.items() if k != "round"}}
               for h in res.history]
    return res.params, history


def dc_setup(Xs_flat: Sequence[np.ndarray], *, m_tilde: int,
             m_hat: Optional[int] = None, anchor_r: int = 2000,
             anchor_kind: str = "uniform", mapping_kind: str = "pca_rot",
             seed: int = 0):
    """Conventional data collaboration [8, 11]: ONE central server holds all
    users' anchor representations, one rank-m̂ SVD, per-user G.

    Returns (mappings, Gs, collab_X_per_user)."""
    m = Xs_flat[0].shape[1]
    m_hat = m_hat or m_tilde
    allX = np.concatenate(list(Xs_flat), axis=0)
    anchor = make_anchor(anchor_kind, seed, anchor_r,
                         feat_min=allX.min(0), feat_max=allX.max(0),
                         public_sample=allX[:: max(1, len(allX) // 512)])
    mappings, inter_A, inter_X = [], [], []
    for u, X in enumerate(Xs_flat):
        f = fit_mapping(mapping_kind, np.asarray(X, np.float64), m_tilde,
                        seed=seed * 1009 + u)
        mappings.append(f)
        inter_A.append(f(anchor))
        inter_X.append(f(np.asarray(X, np.float64)))

    A = np.concatenate(inter_A, axis=1)
    U, s, V = collab.topk_svd(A, m_hat, "host")
    rng = np.random.default_rng(seed * 7)
    Q, R = np.linalg.qr(rng.standard_normal((m_hat, m_hat)))
    Z = U @ (Q * np.sign(np.diag(R))[None, :]) * s[None, :]
    Gs = [collab.solve_G(a, Z) for a in inter_A]
    collab_X = [x @ g for x, g in zip(inter_X, Gs)]
    return mappings, Gs, collab_X
