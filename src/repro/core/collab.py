"""Step 3 of FedDCL: collaboration-representation construction (eqs. 1–3).

Two-level SVD protocol:
  intra-group (eq. 1):  Ã^(i) = [Ã_1^(i) … Ã_{c_i}^(i)] ≈ U^(i) Σ^(i) V^(i)ᵀ
                        B̃^(i) = U^(i) C_1^(i)          (C_1 nonsingular)
  central    (eq. 2):   B̃ = [B̃^(1) … B̃^(d)] ≈ P D Qᵀ,  Z = P C_2
  per-user   (eq. 3):   G_j^(i) = argmin_G ‖Ã_j^(i) G − Z‖_F  (least squares)

Only B̃^(i) crosses the group boundary; only Z comes back. C_1/C_2 follow the
paper's construction C_1^(i) = Σ^(i) (V_{j'}^(i))ᵀ E_1 (random orthogonal E,
randomly selected user block j'), falling back to a random orthogonal matrix
when that product is singular/non-square.

Backends: "host" (NumPy float64 LAPACK — faithful to the paper's MATLAB) and
"tpu" (fp32 Gram reduction via the Pallas `gram` kernel + eigh — DESIGN.md §3
hardware adaptation). Both are covered by agreement tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional, Sequence

import numpy as np


# --------------------------------------------------------------------------
# rank-k SVD with backend dispatch
# --------------------------------------------------------------------------

def topk_svd(A: np.ndarray, k: int, backend: str = "host"):
    """Rank-k thin SVD. Returns (U (n,k), s (k,), V (m,k))."""
    k = int(min(k, *A.shape))
    if backend == "tpu":
        import jax.numpy as jnp
        from repro.kernels.gram import ops as gram_ops
        U, s, V = gram_ops.gram_eigh_topk(jnp.asarray(A, jnp.float32), k)
        return np.asarray(U), np.asarray(s), np.asarray(V)
    U, s, Vt = np.linalg.svd(np.asarray(A, np.float64), full_matrices=False)
    return U[:, :k], s[:k], Vt[:k].T


def _random_orthogonal(rng, k: int) -> np.ndarray:
    Q, R = np.linalg.qr(rng.standard_normal((k, k)))
    return Q * np.sign(np.diag(R))[None, :]


def _obfuscation(rng, s: np.ndarray, V: np.ndarray,
                 block_cols: Sequence[int], k: int) -> np.ndarray:
    """Paper's C = Σ (V_block_j')ᵀ E construction; random-orthogonal fallback
    if the selected block yields a singular / non-square matrix."""
    j = int(rng.integers(0, len(block_cols)))
    lo = int(np.sum(block_cols[:j]))
    hi = lo + int(block_cols[j])
    Vb = V[lo:hi, :]                                  # (m̃_j, k)
    if Vb.shape[0] == k:
        C = (s[:, None] * Vb.T) @ _random_orthogonal(rng, k)
        if np.linalg.cond(C) < 1e8:
            return C
    return _random_orthogonal(rng, k) * s[:, None]


# --------------------------------------------------------------------------
# protocol messages
# --------------------------------------------------------------------------

@dataclass
class GroupBasis:
    """What intra-group DC server i sends to the central FL server."""
    B: np.ndarray                       # (r, m̂_i) = U^(i) C_1^(i)


@dataclass
class CentralTarget:
    """What the central FL server returns to every DC server."""
    Z: np.ndarray                       # (r, m̂) = P C_2


def intra_group_basis(anchors: List[np.ndarray], m_hat_i: int, seed: int,
                      backend: str = "host") -> GroupBasis:
    """Eq. (1) on DC server i. anchors: per-user Ã_j^(i) of shape (r, m̃_ij)."""
    rng = np.random.default_rng(seed)
    A = np.concatenate(anchors, axis=1)               # (r, Σ m̃)
    U, s, V = topk_svd(A, m_hat_i, backend)
    C1 = _obfuscation(rng, s, V, [a.shape[1] for a in anchors], U.shape[1])
    return GroupBasis(B=U @ C1)


def central_target(bases: List[GroupBasis], m_hat: int, seed: int,
                   backend: str = "host") -> CentralTarget:
    """Eq. (2) on the central FL server."""
    rng = np.random.default_rng(seed)
    B = np.concatenate([b.B for b in bases], axis=1)  # (r, Σ m̂_i)
    P, D, Q = topk_svd(B, m_hat, backend)
    C2 = _obfuscation(rng, D, Q, [b.B.shape[1] for b in bases], P.shape[1])
    return CentralTarget(Z=P @ C2)


def solve_G(anchor_j: np.ndarray, Z: np.ndarray) -> np.ndarray:
    """Eq. (3): G = argmin ‖Ã_j G − Z‖_F via least squares."""
    G, *_ = np.linalg.lstsq(anchor_j, Z, rcond=None)
    return G


def alignment_residual(anchor_j: np.ndarray, G: np.ndarray,
                       Z: np.ndarray) -> float:
    """Relative ‖Ã G − Z‖_F / ‖Z‖_F — 0 under Theorem-1 conditions."""
    return float(np.linalg.norm(anchor_j @ G - Z) / max(np.linalg.norm(Z), 1e-12))
