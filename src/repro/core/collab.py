"""Step 3 of FedDCL: collaboration-representation construction (eqs. 1–3).

Two-level SVD protocol:
  intra-group (eq. 1):  Ã^(i) = [Ã_1^(i) … Ã_{c_i}^(i)] ≈ U^(i) Σ^(i) V^(i)ᵀ
                        B̃^(i) = U^(i) C_1^(i)          (C_1 nonsingular)
  central    (eq. 2):   B̃ = [B̃^(1) … B̃^(d)] ≈ P D Qᵀ,  Z = P C_2
  per-user   (eq. 3):   G_j^(i) = argmin_G ‖Ã_j^(i) G − Z‖_F  (least squares)

Only B̃^(i) crosses the group boundary; only Z comes back. C_1/C_2 follow the
paper's construction C_1^(i) = Σ^(i) (V_{j'}^(i))ᵀ E_1 (random orthogonal E,
randomly selected user block j'), falling back to a random orthogonal matrix
when that product is singular/non-square.

Backends (`CollabBackend`, DESIGN.md §3):
  "host"   — NumPy float64 LAPACK, faithful to the paper's MATLAB; serial
             per-group SVDs and per-user `lstsq` calls.
  "device" — device-resident batched engine: all groups go through ONE
             batched fp32 Gram reduction + batched eigh (Pallas `gram`
             kernel on TPU), and all users of the protocol go through ONE
             jitted batched QR least-squares (`solve_G_batched`). Ragged
             group/user widths are zero-padded to the max width.
  "tpu"    — alias of "device" (legacy name).

The obfuscation matrices C_1/C_2 are tiny (m̂ × m̂) and stay on host in both
backends so the two paths share identical RNG streams; because B̃ = U C_1
with C_1 = Σ V_blockᵀ E, per-pair sign flips between eigh- and SVD-derived
factors cancel and the backends agree to fp32 accuracy (tested).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List, Sequence, Tuple

import numpy as np


# --------------------------------------------------------------------------
# padded-ragged helpers
# --------------------------------------------------------------------------

def pad_ragged(mats: Sequence[np.ndarray]) -> Tuple[np.ndarray, np.ndarray]:
    """Stack (r, w_b) matrices of ragged width into a zero-padded
    (B, r, w_max) array + boolean column mask (B, w_max)."""
    r = mats[0].shape[0]
    w_max = max(m.shape[1] for m in mats)
    out = np.zeros((len(mats), r, w_max), np.float32)
    mask = np.zeros((len(mats), w_max), bool)
    for b, m in enumerate(mats):
        out[b, :, : m.shape[1]] = m
        mask[b, : m.shape[1]] = True
    return out, mask


def pad_ragged2d(mats: Sequence[np.ndarray]) -> np.ndarray:
    """Stack matrices ragged in BOTH dims into a zero-padded
    (B, n_max, m_max) float32 array (no masks: callers exploit that
    zero-padding makes the products they need exact — see
    gram.ops.apply_G_batched)."""
    n_max = max(m.shape[0] for m in mats)
    m_max = max(m.shape[1] for m in mats)
    out = np.zeros((len(mats), n_max, m_max), np.float32)
    for b, m in enumerate(mats):
        out[b, : m.shape[0], : m.shape[1]] = m
    return out


def _fix_signs(U: np.ndarray, s: np.ndarray, V: np.ndarray):
    """Deterministic sign convention: make the max-|entry| of each V column
    positive, flipping the (U, V) pair jointly. SVD/eigh factorisations are
    only unique up to per-pair signs; pinning them makes every downstream
    construction — including the non-V-dependent obfuscation fallback —
    agree across backends instead of only the sign-invariant main branch."""
    idx = np.argmax(np.abs(V), axis=0)
    flip = np.sign(V[idx, np.arange(V.shape[1])])
    flip = np.where(flip == 0, 1.0, flip)
    return U * flip[None, :], s, V * flip[None, :]


# --------------------------------------------------------------------------
# backends
# --------------------------------------------------------------------------

class HostBackend:
    """NumPy float64 LAPACK — the paper-faithful serial reference."""

    name = "host"

    def topk_svd(self, A: np.ndarray, k: int):
        k = int(min(k, *A.shape))
        U, s, Vt = np.linalg.svd(np.asarray(A, np.float64), full_matrices=False)
        return _fix_signs(U[:, :k], s[:k], Vt[:k].T)

    def topk_svd_many(self, mats: Sequence[np.ndarray], k: int):
        return [self.topk_svd(A, k) for A in mats]

    def solve_G_many(self, anchors: Sequence[np.ndarray],
                     Z: np.ndarray) -> List[np.ndarray]:
        return [solve_G(A, Z) for A in anchors]

    def apply_G_many(self, Xs: Sequence[np.ndarray],
                     Gs: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Per-user X̂_j = X̃_j G_j — serial float64 matmuls."""
        return [np.asarray(x, np.float64) @ g for x, g in zip(Xs, Gs)]

    # -- incremental onboarding (DESIGN.md §10) ----------------------------

    def gram(self, A: np.ndarray) -> np.ndarray:
        """AᵀA in float64 — the maintained state of a group's anchor stack."""
        A = np.asarray(A, np.float64)
        return A.T @ A

    def gram_update_blocked(self, gram: np.ndarray, A_old: np.ndarray,
                            A_new: np.ndarray) -> np.ndarray:
        """Gram([A_old A_new]) from the maintained Gram(A_old): only the
        cross and new blocks are computed — O(r·W·w) vs O(r·(W+w)²)."""
        A_old = np.asarray(A_old, np.float64)
        A_new = np.asarray(A_new, np.float64)
        cross = A_old.T @ A_new
        return np.block([[gram, cross], [cross.T, A_new.T @ A_new]])

    def topk_svd_from_gram(self, A: np.ndarray, gram: np.ndarray, k: int):
        """Rank-k singular triple recovered from the MAINTAINED Gram:
        eigh(AᵀA) gives (s², V); U = A V / s. Same sign convention as
        `topk_svd`, ~1e-10 relative agreement for separated spectra."""
        A = np.asarray(A, np.float64)
        k = int(min(k, *A.shape))
        evals, evecs = np.linalg.eigh(np.asarray(gram, np.float64))
        s = np.sqrt(np.maximum(evals[::-1][:k], 0.0))
        V = evecs[:, ::-1][:, :k]
        U = (A @ V) / np.maximum(s, 1e-12)[None, :]
        return _fix_signs(U, s, V)

    def factor_G_many(self, anchors: Sequence[np.ndarray]):
        """Per-user reduced QR of Ã_j (float64) — the Z-independent half of
        eq. (3), cached across onboarding events."""
        return [np.linalg.qr(np.asarray(a, np.float64)) for a in anchors]

    def factor_G_append(self, factors, a_new: np.ndarray):
        return list(factors) + [np.linalg.qr(np.asarray(a_new, np.float64))]

    def solve_G_factors(self, factors, Z: np.ndarray) -> List[np.ndarray]:
        """Eq. (3) for every user from cached factors: one triangular solve
        per user against the refreshed target, zero re-factorizations."""
        Z = np.asarray(Z, np.float64)
        return [np.linalg.solve(r, q.T @ Z) for q, r in factors]


class DeviceBackend:
    """Jitted batched path: one Gram+eigh launch for all groups, one QR
    solve for all users. fp32 on-device; outputs returned as NumPy."""

    name = "device"

    def __init__(self, ridge: float = 0.0):
        # relative Tikhonov strength for solve_G_batched; 0.0 keeps exact
        # lstsq agreement and requires full-column-rank anchors (the
        # protocol's generic case) — pass e.g. 1e-3 via
        # get_backend(collab.DeviceBackend(ridge=...)) for degenerate data
        self.ridge = float(ridge)

    def topk_svd(self, A: np.ndarray, k: int):
        return self.topk_svd_many([np.asarray(A)], k)[0]

    def topk_svd_many(self, mats: Sequence[np.ndarray], k: int):
        import jax.numpy as jnp
        from repro.kernels.gram import ops as gram_ops
        padded, _ = pad_ragged(mats)
        # batch at the widest feasible rank, then clamp per matrix exactly
        # like HostBackend.topk_svd (min(k, *A.shape)) — for a narrower
        # matrix the slots past its width hold zero-eigenvalue pairs, so
        # slicing the leading k_b columns recovers its own top-k.
        k_eff = int(min(k, padded.shape[1], padded.shape[2]))
        U, s, V = gram_ops.gram_eigh_topk_batched(jnp.asarray(padded), k_eff)
        U, s, V = np.asarray(U), np.asarray(s), np.asarray(V)
        out = []
        for b, m in enumerate(mats):
            k_b = int(min(k, *m.shape))
            out.append(_fix_signs(U[b][:, :k_b], s[b][:k_b],
                                  V[b, : m.shape[1], :k_b]))
        return out

    def solve_G_many(self, anchors: Sequence[np.ndarray],
                     Z: np.ndarray) -> List[np.ndarray]:
        import jax.numpy as jnp
        from repro.kernels.gram import ops as gram_ops
        padded, mask = pad_ragged(anchors)
        G = gram_ops.solve_G_batched(jnp.asarray(padded),
                                     jnp.asarray(Z, jnp.float32),
                                     jnp.asarray(mask), ridge=self.ridge)
        G = np.asarray(G)
        if not np.all(np.isfinite(G)):
            bad = [b for b in range(len(anchors))
                   if not np.all(np.isfinite(G[b]))]
            raise FloatingPointError(
                f"device least-squares produced non-finite G for users {bad}: "
                "anchor columns are (near-)collinear, which the QR path "
                "cannot handle at ridge=0 — use collab.DeviceBackend("
                "ridge=1e-3) as svd_backend, or svd_backend='host'")
        return [G[b, : a.shape[1]] for b, a in enumerate(anchors)]

    def apply_G_many(self, Xs: Sequence[np.ndarray],
                     Gs: Sequence[np.ndarray]) -> List[np.ndarray]:
        """Per-user X̂_j = X̃_j G_j for ALL users in ONE batched device
        matmul: X̃ zero-padded on both axes, G zero-padded on rows — the
        real blocks of the products are exact because padded columns of X̃
        only ever multiply zero rows of G (padded sample rows are sliced
        away)."""
        import jax.numpy as jnp
        from repro.kernels.gram import ops as gram_ops
        Xp = pad_ragged2d(Xs)                             # (U, n_max, m̃_max)
        Gp = pad_ragged2d(Gs)                             # (U, m̃_max, m̂)
        out = np.asarray(gram_ops.apply_G_batched(jnp.asarray(Xp),
                                                  jnp.asarray(Gp)))
        return [out[u, : x.shape[0], : g.shape[1]]
                for u, (x, g) in enumerate(zip(Xs, Gs))]

    # -- incremental onboarding (DESIGN.md §10) ----------------------------

    def gram(self, A: np.ndarray) -> np.ndarray:
        """AᵀA via the device Gram reduction (fp32) — same arithmetic the
        batched from-scratch path uses, so maintained and recomputed Grams
        agree to fp32 roundoff."""
        import jax.numpy as jnp
        from repro.kernels.gram import ops as gram_ops
        return np.asarray(gram_ops.gram(jnp.asarray(A, jnp.float32)))

    def gram_update_blocked(self, gram: np.ndarray, A_old: np.ndarray,
                            A_new: np.ndarray) -> np.ndarray:
        """Blocked device update: one jitted launch computing only the
        cross/new blocks (gram_ops.gram_append_blocked, B=1)."""
        import jax.numpy as jnp
        from repro.kernels.gram import ops as gram_ops
        out = gram_ops.gram_append_blocked(
            jnp.asarray(gram, jnp.float32)[None],
            jnp.asarray(A_old, jnp.float32)[None],
            jnp.asarray(A_new, jnp.float32)[None])
        return np.asarray(out[0])

    def topk_svd_from_gram(self, A: np.ndarray, gram: np.ndarray, k: int):
        """Batched eigh+recovery from the maintained Gram (B=1) — the same
        `eigh_topk_recover_batched` tail the from-scratch device SVD runs,
        just fed the incrementally-updated Gram."""
        import jax.numpy as jnp
        from repro.kernels.gram import ops as gram_ops
        k_eff = int(min(k, *A.shape))
        U, s, V = gram_ops.eigh_topk_recover_batched(
            jnp.asarray(gram, jnp.float32)[None],
            jnp.asarray(A, jnp.float32)[None], k_eff)
        return _fix_signs(np.asarray(U[0]), np.asarray(s[0]),
                          np.asarray(V[0]))

    def factor_G_many(self, anchors: Sequence[np.ndarray]):
        """ONE batched QR factorization of the (padded) augmented anchor
        stack — the Z-independent half of `solve_G_batched`, cached."""
        import jax.numpy as jnp
        from repro.kernels.gram import ops as gram_ops
        padded, mask = pad_ragged(anchors)
        q, rr = gram_ops.solve_G_factor_batched(
            jnp.asarray(padded), jnp.asarray(mask), ridge=self.ridge)
        return {"q": q, "rr": rr, "mask": mask,
                "r": padded.shape[1],
                "widths": [a.shape[1] for a in anchors]}

    def factor_G_append(self, factors, a_new: np.ndarray):
        """Factor ONLY the joining tenant (B=1 at the stack's pad width) and
        append it to the cached factor stack. Returns None when the new
        anchor is wider than the current pad width (or taller than the
        factored row count) — the caller re-factors the whole group then."""
        import jax.numpy as jnp
        from repro.kernels.gram import ops as gram_ops
        m_max = factors["mask"].shape[1]
        if a_new.shape[1] > m_max or a_new.shape[0] != factors["r"]:
            return None
        padded, mask = pad_ragged([a_new])
        if m_max > padded.shape[2]:
            pad = m_max - padded.shape[2]
            padded = np.pad(padded, ((0, 0), (0, 0), (0, pad)))
            mask = np.pad(mask, ((0, 0), (0, pad)))
        q1, rr1 = gram_ops.solve_G_factor_batched(
            jnp.asarray(padded), jnp.asarray(mask), ridge=self.ridge)
        return {"q": jnp.concatenate([factors["q"], q1], axis=0),
                "rr": jnp.concatenate([factors["rr"], rr1], axis=0),
                "mask": np.concatenate([factors["mask"], mask], axis=0),
                "r": factors["r"],
                "widths": factors["widths"] + [a_new.shape[1]]}

    def solve_G_factors(self, factors, Z: np.ndarray) -> List[np.ndarray]:
        """All users of a group re-solved against a refreshed Z in ONE
        batched triangular solve from the cached factors."""
        import jax.numpy as jnp
        from repro.kernels.gram import ops as gram_ops
        G = np.asarray(gram_ops.solve_G_from_factors(
            factors["q"], factors["rr"], jnp.asarray(Z, jnp.float32),
            jnp.asarray(factors["mask"])))
        if not np.all(np.isfinite(G)):
            bad = [b for b in range(G.shape[0])
                   if not np.all(np.isfinite(G[b]))]
            raise FloatingPointError(
                f"device least-squares produced non-finite G for users {bad} "
                "from cached factors — see DeviceBackend.solve_G_many")
        return [G[b, :w] for b, w in enumerate(factors["widths"])]


_BACKENDS = {"host": HostBackend, "device": DeviceBackend, "tpu": DeviceBackend}


def get_backend(name: str):
    """Resolve a backend name ("host" | "device" | "tpu") or pass through an
    object already implementing the CollabBackend protocol."""
    if isinstance(name, str):
        try:
            return _BACKENDS[name]()
        except KeyError:
            raise ValueError(
                f"unknown collab backend {name!r}; choose from {sorted(_BACKENDS)}")
    return name


# --------------------------------------------------------------------------
# rank-k SVD with backend dispatch (legacy single-matrix entry point)
# --------------------------------------------------------------------------

def topk_svd(A: np.ndarray, k: int, backend: str = "host"):
    """Rank-k thin SVD. Returns (U (n,k), s (k,), V (m,k))."""
    return get_backend(backend).topk_svd(A, k)


def _random_orthogonal(rng, k: int) -> np.ndarray:
    Q, R = np.linalg.qr(rng.standard_normal((k, k)))
    return Q * np.sign(np.diag(R))[None, :]


def _obfuscation(rng, s: np.ndarray, V: np.ndarray,
                 block_cols: Sequence[int], k: int) -> np.ndarray:
    """Paper's C = Σ (V_block_j')ᵀ E construction; random-orthogonal fallback
    if the selected block yields a singular / non-square matrix."""
    j = int(rng.integers(0, len(block_cols)))
    lo = int(np.sum(block_cols[:j]))
    hi = lo + int(block_cols[j])
    Vb = V[lo:hi, :]                                  # (m̃_j, k)
    if Vb.shape[0] == k:
        C = (s[:, None] * Vb.T) @ _random_orthogonal(rng, k)
        if np.linalg.cond(C) < 1e8:
            return C
    return _random_orthogonal(rng, k) * s[:, None]


# --------------------------------------------------------------------------
# protocol messages
# --------------------------------------------------------------------------

@dataclass
class GroupBasis:
    """What intra-group DC server i sends to the central FL server."""
    B: np.ndarray                       # (r, m̂_i) = U^(i) C_1^(i)


@dataclass
class CentralTarget:
    """What the central FL server returns to every DC server."""
    Z: np.ndarray                       # (r, m̂) = P C_2


def _basis_from_svd(svd, rng, block_cols: Sequence[int]) -> GroupBasis:
    U, s, V = svd
    C1 = _obfuscation(rng, s, V, block_cols, U.shape[1])
    return GroupBasis(B=U @ C1)


def intra_group_basis(anchors: List[np.ndarray], m_hat_i: int, seed: int,
                      backend: str = "host") -> GroupBasis:
    """Eq. (1) on DC server i. anchors: per-user Ã_j^(i) of shape (r, m̃_ij)."""
    rng = np.random.default_rng(seed)
    A = np.concatenate(anchors, axis=1)               # (r, Σ m̃)
    svd = get_backend(backend).topk_svd(A, m_hat_i)
    return _basis_from_svd(svd, rng, [a.shape[1] for a in anchors])


def intra_group_bases(anchor_groups: Sequence[Sequence[np.ndarray]],
                      m_hat: int, seeds: Sequence[int],
                      backend: str = "host") -> List[GroupBasis]:
    """Eq. (1) for ALL d DC servers at once. On the device backend the d
    stacked-anchor matrices (ragged widths, zero-padded) go through a single
    batched Gram+eigh launch; on host this is the serial per-group loop."""
    be = get_backend(backend)
    stacked = [np.concatenate(list(g), axis=1) for g in anchor_groups]
    svds = be.topk_svd_many(stacked, m_hat)
    return [
        _basis_from_svd(svd, np.random.default_rng(seed),
                        [a.shape[1] for a in group])
        for svd, seed, group in zip(svds, seeds, anchor_groups)
    ]


def central_target(bases: List[GroupBasis], m_hat: int, seed: int,
                   backend: str = "host") -> CentralTarget:
    """Eq. (2) on the central FL server."""
    rng = np.random.default_rng(seed)
    B = np.concatenate([b.B for b in bases], axis=1)  # (r, Σ m̂_i)
    P, D, Q = get_backend(backend).topk_svd(B, m_hat)
    C2 = _obfuscation(rng, D, Q, [b.B.shape[1] for b in bases], P.shape[1])
    return CentralTarget(Z=P @ C2)


def solve_G(anchor_j: np.ndarray, Z: np.ndarray) -> np.ndarray:
    """Eq. (3): G = argmin ‖Ã_j G − Z‖_F via least squares."""
    G, *_ = np.linalg.lstsq(anchor_j, Z, rcond=None)
    return G


def solve_G_all(anchors: Sequence[np.ndarray], Z: np.ndarray,
                backend: str = "host") -> List[np.ndarray]:
    """Eq. (3) for a flat list of users. The device backend pads the ragged
    anchor widths and answers with ONE batched QR solve — zero per-user
    `lstsq` calls."""
    return get_backend(backend).solve_G_many(anchors, Z)


def apply_G_all(Xs: Sequence[np.ndarray], Gs: Sequence[np.ndarray],
                backend: str = "host") -> List[np.ndarray]:
    """Step 12: collaboration representations X̂_j = X̃_j G_j for a flat list
    of users. The device backend runs ONE padded batched matmul for all
    users (zero per-user host matmuls); host is the serial float64 loop."""
    return get_backend(backend).apply_G_many(Xs, Gs)


def alignment_residual(anchor_j: np.ndarray, G: np.ndarray,
                       Z: np.ndarray) -> float:
    """Relative ‖Ã G − Z‖_F / ‖Z‖_F — 0 under Theorem-1 conditions."""
    return float(np.linalg.norm(anchor_j @ G - Z) / max(np.linalg.norm(Z), 1e-12))
