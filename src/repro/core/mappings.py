"""Step 2 of FedDCL: each user's PRIVATE dimensionality-reduction map f_j^(i).

A mapping is a linear row-wise map f(X) = (X - mu) W with W ∈ R^{m × m̃},
never shared under the protocol (privacy Layer 1). Kinds:

  pca_rot  — top-m̃ local PCA basis composed with a RANDOM ORTHOGONAL
             rotation (the paper's experimental setting): W = V_k Q.
             Range(W) = local principal subspace; the rotation makes W
             user-specific even for identical data.
  pca      — plain local PCA (used by the Theorem-1 property test: all
             users on identical data then share Range(W)).
  randproj — Gaussian random projection (Johnson-Lindenstrauss), data-free.
  fixed    — externally supplied W (test hook for same-range constructions).

Nonlinear maps are supported by composing `apply` with any row-wise
nonlinearity upstream; the paper's experiments (and ours) use linear maps.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import numpy as np


@dataclass
class LinearMap:
    mu: np.ndarray        # (m,)
    W: np.ndarray         # (m, m_tilde)

    def __call__(self, X: np.ndarray) -> np.ndarray:
        return (X - self.mu[None, :]) @ self.W

    @property
    def out_dim(self) -> int:
        return self.W.shape[1]


def _random_orthogonal(rng, k: int) -> np.ndarray:
    Q, R = np.linalg.qr(rng.standard_normal((k, k)))
    return Q * np.sign(np.diag(R))[None, :]


def fit_mapping(kind: str, X: np.ndarray, m_tilde: int,
                seed: int = 0, center: bool = True,
                W: Optional[np.ndarray] = None) -> LinearMap:
    rng = np.random.default_rng(seed)
    m = X.shape[1]
    mu = X.mean(axis=0) if center else np.zeros(m)
    if kind == "fixed":
        assert W is not None
        return LinearMap(mu=mu, W=np.asarray(W, np.float64))
    if kind == "randproj":
        Wr = rng.standard_normal((m, m_tilde)) / np.sqrt(m_tilde)
        return LinearMap(mu=mu, W=Wr)
    # PCA variants
    Xc = X - mu[None, :]
    _, _, Vt = np.linalg.svd(Xc, full_matrices=False)
    V = Vt[:m_tilde].T                                  # (m, m̃)
    if kind == "pca":
        return LinearMap(mu=mu, W=V)
    if kind == "pca_rot":
        Q = _random_orthogonal(rng, m_tilde)
        return LinearMap(mu=mu, W=V @ Q)
    raise ValueError(f"unknown mapping kind {kind!r}")
