"""Privacy evaluation of FedDCL's double protection layer (§3.4) and the
hostile-world attacker harness (DESIGN.md §8).

Layer 1 (protocol): f_j^(i) is never shared — an attacker on a DC server
sees only X̃ = (X − μ)W with unknown (μ, W).
Layer 2 (ε-DR privacy [25]): even with f stolen, W is a dimensionality
reduction (m̃ < m), so X is not recoverable beyond the best rank-m̃
approximation.

Metrics:
  recovery_error_known_map    — ‖X − X̂‖/‖X‖ with X̂ = X̃ W⁺ + μ  (Layer-2 bound)
  recovery_error_unknown_map  — same attack with a random W′ of the right
                                shape (Layer-1: attacker has no map)
  eps_dr                      — ε-DR privacy level: per-sample guaranteed
                                floor ε s.t. ‖x − x̂‖² ≥ ε‖x‖² for the optimal
                                linear reconstruction (1 − top-m̃ energy ratio)

Attacker harness (active adversaries at the FedAvg boundary; consumed by
run_federated and experiments/robust_ablation.py):
  SiloAttack              — which silos are corrupted and how
  label_flip_silos        — data poisoning: corrupted silos' labels flipped
                            (classification: cyclic shift; regression:
                            negated) BEFORE training — the model update is
                            honest SGD on dishonest data
  grad_scale_vector       — model poisoning: the (d,) silo_scale argument
                            scaling corrupted silos' submitted round deltas
                            (core/federated.apply_silo_scale; scale < 0
                            pushes the global model AWAY from the honest
                            average — the classic sign-flip attacker)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.core.mappings import LinearMap


def recovery_error_known_map(X: np.ndarray, f: LinearMap) -> float:
    Xt = f(X)
    W_pinv = np.linalg.pinv(f.W)
    X_rec = Xt @ W_pinv + f.mu[None, :]
    return float(np.linalg.norm(X - X_rec) / max(np.linalg.norm(X), 1e-12))


def recovery_error_unknown_map(X: np.ndarray, f: LinearMap, seed: int = 0) -> float:
    """Layer-1 attack: the adversary sees X̃ but must guess the map."""
    rng = np.random.default_rng(seed)
    Xt = f(X)
    W_guess = rng.standard_normal(f.W.shape)
    X_rec = Xt @ np.linalg.pinv(W_guess)              # no μ either
    return float(np.linalg.norm(X - X_rec) / max(np.linalg.norm(X), 1e-12))


def eps_dr(X: np.ndarray, m_tilde: int) -> float:
    """ε-DR privacy level of ANY rank-m̃ linear reduction of X: the optimal
    reconstruction leaves at least the (m̃+1..m) tail energy, so
    ε = 1 − Σ_{k≤m̃} σ_k² / Σ_k σ_k²."""
    Xc = X - X.mean(0, keepdims=True)
    s = np.linalg.svd(Xc, compute_uv=False)
    total = float(np.sum(s ** 2))
    kept = float(np.sum(s[:m_tilde] ** 2))
    return max(0.0, 1.0 - kept / max(total, 1e-12))


def evaluate(X: np.ndarray, f: LinearMap, seed: int = 0) -> Dict[str, float]:
    return {
        "recovery_error_known_map": recovery_error_known_map(X, f),
        "recovery_error_unknown_map": recovery_error_unknown_map(X, f, seed),
        "eps_dr": eps_dr(X, f.out_dim),
    }


# ==========================================================================
# Active attacker harness (hostile-world federation, DESIGN.md §8)
# ==========================================================================

@dataclass(frozen=True)
class SiloAttack:
    """One adversarial configuration of a federated run.

    corrupted: indices of the Byzantine silos (empty = honest run).
    kind: "none" | "label_flip" | "grad_scale".
    scale: the delta multiplier grad_scale applies at the corrupted silos
      (−5.0 default: a sign-flipped, amplified submission — far outside the
      honest cluster, the regime robust aggregators are built for).
    num_classes: needed by label_flip on classification targets.
    """
    corrupted: Tuple[int, ...] = ()
    kind: str = "none"
    scale: float = -5.0
    num_classes: int = 0

    def __post_init__(self):
        if self.kind not in ("none", "label_flip", "grad_scale"):
            raise ValueError(f"unknown attack kind {self.kind!r}")


def label_flip_silos(
    silo_data: Sequence[Tuple[np.ndarray, np.ndarray]],
    corrupted: Sequence[int], *, num_classes: int = 0,
) -> List[Tuple[np.ndarray, np.ndarray]]:
    """Data-poisoning attacker: return a copy of silo_data with the
    corrupted silos' labels flipped. Classification labels are cyclically
    shifted ((y+1) mod C — every label wrong, the strongest untargeted
    flip); regression targets are negated. Honest silos share storage with
    the input (no copy)."""
    bad = set(int(i) for i in corrupted)
    out: List[Tuple[np.ndarray, np.ndarray]] = []
    for i, (x, y) in enumerate(silo_data):
        if i not in bad:
            out.append((x, y))
            continue
        y = np.asarray(y)
        if num_classes > 0:
            yf = np.mod(y.astype(np.int64) + 1, num_classes).astype(y.dtype)
        else:
            yf = -y
        out.append((x, yf))
    return out


def grad_scale_vector(num_silos: int, corrupted: Sequence[int],
                      scale: float = -5.0) -> np.ndarray:
    """Model-poisoning attacker: the (num_silos,) silo_scale vector for
    run_federated — corrupted silos submit scale·delta, honest silos 1.0
    (an exact no-op, core/federated.apply_silo_scale)."""
    v = np.ones(num_silos, np.float32)
    for i in corrupted:
        if not 0 <= int(i) < num_silos:
            raise ValueError(f"corrupted silo {i} out of range "
                             f"[0, {num_silos})")
        v[int(i)] = np.float32(scale)
    return v


def apply_attack(
    silo_data: Sequence[Tuple[np.ndarray, np.ndarray]],
    attack: SiloAttack,
) -> Tuple[List[Tuple[np.ndarray, np.ndarray]], "np.ndarray | None"]:
    """Materialize an attack: returns (possibly-poisoned silo_data,
    silo_scale-or-None) — the pair run_federated consumes. label_flip
    rewrites data and leaves scale honest; grad_scale leaves data intact
    and returns the scale vector."""
    if attack.kind == "none" or not attack.corrupted:
        return list(silo_data), None
    if attack.kind == "label_flip":
        return label_flip_silos(silo_data, attack.corrupted,
                                num_classes=attack.num_classes), None
    return list(silo_data), grad_scale_vector(
        len(silo_data), attack.corrupted, attack.scale)
