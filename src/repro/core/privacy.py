"""Privacy evaluation of FedDCL's double protection layer (§3.4).

Layer 1 (protocol): f_j^(i) is never shared — an attacker on a DC server
sees only X̃ = (X − μ)W with unknown (μ, W).
Layer 2 (ε-DR privacy [25]): even with f stolen, W is a dimensionality
reduction (m̃ < m), so X is not recoverable beyond the best rank-m̃
approximation.

Metrics:
  recovery_error_known_map    — ‖X − X̂‖/‖X‖ with X̂ = X̃ W⁺ + μ  (Layer-2 bound)
  recovery_error_unknown_map  — same attack with a random W′ of the right
                                shape (Layer-1: attacker has no map)
  eps_dr                      — ε-DR privacy level: per-sample guaranteed
                                floor ε s.t. ‖x − x̂‖² ≥ ε‖x‖² for the optimal
                                linear reconstruction (1 − top-m̃ energy ratio)
"""
from __future__ import annotations

from typing import Dict

import numpy as np

from repro.core.mappings import LinearMap


def recovery_error_known_map(X: np.ndarray, f: LinearMap) -> float:
    Xt = f(X)
    W_pinv = np.linalg.pinv(f.W)
    X_rec = Xt @ W_pinv + f.mu[None, :]
    return float(np.linalg.norm(X - X_rec) / max(np.linalg.norm(X), 1e-12))


def recovery_error_unknown_map(X: np.ndarray, f: LinearMap, seed: int = 0) -> float:
    """Layer-1 attack: the adversary sees X̃ but must guess the map."""
    rng = np.random.default_rng(seed)
    Xt = f(X)
    W_guess = rng.standard_normal(f.W.shape)
    X_rec = Xt @ np.linalg.pinv(W_guess)              # no μ either
    return float(np.linalg.norm(X - X_rec) / max(np.linalg.norm(X), 1e-12))


def eps_dr(X: np.ndarray, m_tilde: int) -> float:
    """ε-DR privacy level of ANY rank-m̃ linear reduction of X: the optimal
    reconstruction leaves at least the (m̃+1..m) tail energy, so
    ε = 1 − Σ_{k≤m̃} σ_k² / Σ_k σ_k²."""
    Xc = X - X.mean(0, keepdims=True)
    s = np.linalg.svd(Xc, compute_uv=False)
    total = float(np.sum(s ** 2))
    kept = float(np.sum(s[:m_tilde] ** 2))
    return max(0.0, 1.0 - kept / max(total, 1e-12))


def evaluate(X: np.ndarray, f: LinearMap, seed: int = 0) -> Dict[str, float]:
    return {
        "recovery_error_known_map": recovery_error_known_map(X, f),
        "recovery_error_unknown_map": recovery_error_unknown_map(X, f, seed),
        "eps_dr": eps_dr(X, f.out_dim),
    }
