"""One-call public API: ``FedDCL().fit(Xs, Ys)`` — protocol steps 1–3 plus
the compiled FL phase, through the compiled-plan cache.

The paper's pitch is that institutions pay for communication once and
amortize everything else; this facade makes the COMPUTE side match. The
first ``fit()`` of a given shape bucket pays the scan-engine trace+compile
(~1 s on CPU); every later ``fit()`` whose padded shapes land in the same
bucket reuses the executable and costs milliseconds (the plan cache,
core/federated.py, DESIGN.md §6). Across processes, the persistent XLA
compilation cache (``FEDDCL_COMPILATION_CACHE``) turns even the first call
of a fresh process into a disk hit.

    from repro.api import FedDCL
    model = FedDCL(m_tilde=8, rounds=20, local_epochs=4, task="regression")
    setup, result = model.fit(Xs, Ys)      # Xs[i][j]: raw data of user (i,j)
    yhat = model.predict(Xnew)             # through user (0,0)'s transform
    result.cache_stats                     # {'hit': ..., 'misses': ...}

Everything is keyword-configured with the paper's §4.1 defaults; the
returned ``setup`` is the full FedDCLSetup (mappings, G's, comm log) and
``result`` the FLResult of the federated phase.
"""
from __future__ import annotations

import os
from functools import partial
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import protocol
from repro.core.federated import (FLResult, PlanCache, default_plan_cache,
                                  run_federated)
from repro.core.protocol import FedDCLSetup
from repro.models import mlp
from repro.optim import adamw

_COMPILE_CACHE_ENABLED: Optional[str] = None


def enable_persistent_compilation_cache(cache_dir: Optional[str] = None) -> Optional[str]:
    """Point XLA's persistent compilation cache at `cache_dir` (default: the
    ``FEDDCL_COMPILATION_CACHE`` env var) so compiled executables survive
    process boundaries — CI and benchmark sweeps set the env var and every
    fresh process starts warm. No-op when neither is set; idempotent;
    returns the active directory (or None).

    Thresholds are dropped to zero because the FL-phase programs are small,
    fast-compiling HLO by XLA's heuristics yet dominate our cold time.
    """
    global _COMPILE_CACHE_ENABLED
    cache_dir = cache_dir or os.environ.get("FEDDCL_COMPILATION_CACHE")
    if not cache_dir:
        return _COMPILE_CACHE_ENABLED
    if _COMPILE_CACHE_ENABLED == cache_dir:
        return cache_dir
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    for flag, val in (("jax_persistent_cache_min_entry_size_bytes", -1),
                      ("jax_persistent_cache_min_compile_time_secs", 0)):
        try:
            jax.config.update(flag, val)
        except AttributeError:       # older jax: thresholds keep defaults
            pass
    # jax latches cache-off at the first compile of the process; reset so
    # enabling mid-process (any compile may already have happened) works
    try:
        from jax._src import compilation_cache as _cc
        _cc.reset_cache()
    except Exception:
        pass
    _COMPILE_CACHE_ENABLED = cache_dir
    return cache_dir


class FedDCL:
    """sklearn-style facade over the full FedDCL pipeline.

    ``fit(Xs, Ys)`` runs Algorithm 1 end to end: anchor + private mappings
    (steps 1–2), the two-level collaboration solve (step 3, `svd_backend`),
    then the federated phase (step 4) on the collaboration representations
    through ``run_federated`` — by default on the compiled scan engine via
    the shared plan cache, with stable loss/optimizer cache identities so
    repeated fits and sweeps reuse executables.

    Model head: an MLP on the m̂-dimensional collaboration representations
    (`hidden`, `task`; `out_dim` inferred from Ys when None).
    """

    def __init__(self, *, m_tilde: int, m_hat: Optional[int] = None,
                 hidden: Sequence[int] = (32,), task: str = "regression",
                 out_dim: Optional[int] = None,
                 rounds: int = 20, local_epochs: int = 4,
                 batch_size: int = 32, lr: float = 1e-3,
                 aggregator: str = "fedavg", fedprox_mu: float = 0.0,
                 anchor_r: int = 2000, anchor_kind: str = "uniform",
                 mapping_kind: str = "pca_rot", svd_backend: str = "host",
                 engine: str = "scan", seed: int = 0,
                 reset_opt_per_round: bool = True,
                 cache: Any = True,
                 eval_fn: Optional[Callable[[Any], Dict[str, float]]] = None,
                 dropout_rate: float = 0.0,
                 silo_scale: Optional[Sequence[float]] = None,
                 trim_frac: float = 0.2, krum_f: int = 1,
                 onboard: bool = True):
        self.m_tilde = m_tilde
        self.m_hat = m_hat or m_tilde
        self.hidden = tuple(hidden)
        self.task = task
        self.out_dim = out_dim
        self.rounds = rounds
        self.local_epochs = local_epochs
        self.batch_size = batch_size
        self.lr = lr
        self.aggregator = aggregator
        self.fedprox_mu = fedprox_mu
        self.anchor_r = anchor_r
        self.anchor_kind = anchor_kind
        self.mapping_kind = mapping_kind
        self.svd_backend = svd_backend
        self.engine = engine
        self.seed = seed
        self.reset_opt_per_round = reset_opt_per_round
        self.cache = cache
        self.eval_fn = eval_fn
        # hostile-world federation knobs (DESIGN.md §8): aggregator may be
        # any of federated.AGGREGATORS incl. the robust ones; dropout_rate
        # simulates silo unavailability; silo_scale is the attack-injection
        # vector (experiments/robust_ablation.py exercises all of these)
        self.dropout_rate = dropout_rate
        self.silo_scale = silo_scale
        self.trim_frac = trim_frac
        self.krum_f = krum_f
        # onboard=True keeps the incremental-update state (cached Grams and
        # QR factors, DESIGN.md §10) so partial_fit()/serve().onboard_* can
        # admit tenants without a full protocol recompute
        self.onboard = onboard
        # one optimizer per estimator: its identity is stable across fit()s
        self._opt = adamw(lr)
        self.setup_: Optional[FedDCLSetup] = None
        self.result_: Optional[FLResult] = None

    # -- pipeline ----------------------------------------------------------

    def _infer_out_dim(self, Ys) -> int:
        if self.out_dim is not None:
            return self.out_dim
        y0 = np.asarray(Ys[0][0])
        if self.task == "classification":
            return int(max(int(np.asarray(y).max()) for g in Ys for y in g)) + 1
        return 1 if y0.ndim == 1 else int(y0.shape[-1])

    def fit(self, Xs: Sequence[Sequence[np.ndarray]],
            Ys: Sequence[Sequence[np.ndarray]],
            init_params: Any = None) -> Tuple[FedDCLSetup, FLResult]:
        """Run the whole protocol; returns (setup, fl_result) and stores
        them on the estimator (`setup_`, `result_`, `params_`)."""
        enable_persistent_compilation_cache()
        setup = protocol.run_protocol(
            Xs, Ys, m_tilde=self.m_tilde, m_hat=self.m_hat,
            anchor_r=self.anchor_r, anchor_kind=self.anchor_kind,
            mapping_kind=self.mapping_kind, seed=self.seed,
            svd_backend=self.svd_backend, onboard=self.onboard)
        out_dim = self._infer_out_dim(Ys)
        params = init_params if init_params is not None else mlp.init_mlp_params(
            jax.random.PRNGKey(self.seed), self.m_hat, self.hidden, out_dim)
        loss = partial(mlp.mlp_per_example_loss, task=self.task)
        result = run_federated(
            loss, params, setup.fed_silos(), opt=self._opt,
            rounds=self.rounds, local_epochs=self.local_epochs,
            batch_size=self.batch_size, aggregator=self.aggregator,
            fedprox_mu=self.fedprox_mu, seed=self.seed, eval_fn=self.eval_fn,
            engine=self.engine, cache=self.cache if self.engine == "scan" else None,
            loss_id=("mlp_per_example_loss", self.task),
            opt_id=("adamw", self.lr),
            dropout_rate=self.dropout_rate, silo_scale=self.silo_scale,
            trim_frac=self.trim_frac, krum_f=self.krum_f)
        self.setup_, self.result_ = setup, result
        self.params_ = result.params
        return setup, result

    # -- incremental onboarding (DESIGN.md §10) ----------------------------

    def partial_fit(self, X_new: Any, Y_new: Any, *,
                    group: Optional[int] = None,
                    refit_rounds: Optional[int] = None) -> Tuple[int, int]:
        """Onboard new data onto a FITTED estimator without recomputing the
        protocol: with ``group=i``, (X_new, Y_new) is ONE new user joining
        group i; with ``group=None``, they are lists of per-user arrays
        forming a whole new silo. The collaboration solve updates
        incrementally (blocked Gram + cached factors; equal to a from-scratch
        ``run_protocol`` on the same anchor, tested to 1e-5).

        ``refit_rounds`` optionally continues federated training for that
        many rounds on the refreshed representations, warm-starting from the
        current params (the central SVD moved, so every silo's X̂ changed
        slightly). Returns the (group, user) index of the newcomer.
        """
        if self.setup_ is None:
            raise RuntimeError("call fit() before partial_fit()")
        if group is None:
            i = self.setup_.onboard_silo(list(X_new), list(Y_new))
            j = 0
        else:
            i = int(group)
            j = self.setup_.onboard_user(i, X_new, Y_new)
        if refit_rounds:
            loss = partial(mlp.mlp_per_example_loss, task=self.task)
            result = run_federated(
                loss, self.params_, self.setup_.fed_silos(), opt=self._opt,
                rounds=int(refit_rounds), local_epochs=self.local_epochs,
                batch_size=self.batch_size, aggregator=self.aggregator,
                fedprox_mu=self.fedprox_mu, seed=self.seed + 1,
                eval_fn=self.eval_fn, engine=self.engine,
                cache=self.cache if self.engine == "scan" else None,
                loss_id=("mlp_per_example_loss", self.task),
                opt_id=("adamw", self.lr),
                dropout_rate=self.dropout_rate, silo_scale=self.silo_scale,
                trim_frac=self.trim_frac, krum_f=self.krum_f)
            self.result_ = result
            self.params_ = result.params
        return i, j

    def serve(self, **kw) -> Any:
        """A live ``ServeCollab`` server over the fitted model: queued,
        bucketed, continuously-admitted inference for every tenant, with
        ``onboard_user``/``onboard_silo`` for admitting tenants in place."""
        from repro.serve_collab import ServeCollab
        return ServeCollab.from_model(self, **kw)

    # -- inference ---------------------------------------------------------

    def transform(self, X: np.ndarray, i: int = 0, j: int = 0) -> np.ndarray:
        """x → f_j^(i)(x) G_j^(i): user (i,j)'s input map."""
        if self.setup_ is None:
            raise RuntimeError("call fit() first")
        return np.asarray(self.setup_.user_transform(i, j)(X))

    def predict(self, X: np.ndarray, i: int = 0, j: int = 0) -> np.ndarray:
        """t_j^(i)(X) = h(f(X) G): regression values or class labels."""
        if self.result_ is None:
            raise RuntimeError("call fit() first")
        out = np.asarray(mlp.mlp_forward(self.params_,
                                         np.asarray(self.transform(X, i, j),
                                                    np.float32)))
        return out.argmax(-1) if self.task == "classification" else out

    def score(self, X: np.ndarray, Y: np.ndarray, i: int = 0, j: int = 0) -> float:
        """RMSE (regression) / accuracy (classification) through (i,j)."""
        import jax.numpy as jnp
        Xt = jnp.asarray(self.transform(X, i, j), jnp.float32)
        return mlp.mlp_metric(self.params_, Xt, jnp.asarray(Y), self.task)
