"""Layer library: norms, RoPE, attention (GQA / sliding-window / softcap /
qk-norm), MLA, SwiGLU MLP, MoE, RWKV6 time/channel mix, Mamba2 (SSD).

Functional style: ``init_*`` builds a dict pytree of parameters,
``apply_*`` consumes it. No framework dependency (flax is not installed).

Dtype convention: params live in ``param_dtype``; activations are computed in
``compute_dtype`` (bf16 on TPU) with fp32 accumulation where it matters
(softmax, norms, recurrent states, router logits).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, SSMConfig
from repro.shardingx.constrain import constrain

Params = Dict[str, Any]


# --------------------------------------------------------------------------
# init helpers
# --------------------------------------------------------------------------

def _dense_init(key, shape, in_axis_size, dtype):
    """Truncated-normal fan-in init (matches common LLM init scales)."""
    std = 1.0 / math.sqrt(max(in_axis_size, 1))
    return (jax.random.truncated_normal(key, -3.0, 3.0, shape, jnp.float32) * std).astype(dtype)


def _zeros(shape, dtype):
    return jnp.zeros(shape, dtype)


def _ones(shape, dtype):
    return jnp.ones(shape, dtype)


# --------------------------------------------------------------------------
# RMSNorm
# --------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype) -> Params:
    return {"scale": _ones((d,), dtype)}


def apply_rmsnorm(p: Params, x: jnp.ndarray, eps: float = 1e-5,
                  zero_centered: bool = False) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    xf = xf * lax.rsqrt(var + eps)
    scale = p["scale"].astype(jnp.float32)
    if zero_centered:           # gemma-style (1 + scale)
        scale = 1.0 + scale
    return (xf * scale).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_angles(positions: jnp.ndarray, head_dim: int, theta: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """positions: (...,) int -> sin/cos of shape (..., head_dim//2), fp32."""
    half = head_dim // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32) * (math.log(theta) / half))
    ang = positions.astype(jnp.float32)[..., None] * freqs  # (..., half)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jnp.ndarray, sin: jnp.ndarray, cos: jnp.ndarray) -> jnp.ndarray:
    """x: (..., head_dim); sin/cos broadcastable to (..., head_dim//2).

    Rotates pairs (x[..., :half], x[..., half:]) — "half" layout.
    """
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half].astype(jnp.float32), x[..., half:].astype(jnp.float32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def _bcast_rope(sin: jnp.ndarray, cos: jnp.ndarray):
    """(B, S, half) -> (B, S, 1, half) to broadcast over heads."""
    return sin[..., None, :], cos[..., None, :]


# --------------------------------------------------------------------------
# Attention (GQA, sliding window, softcap, qk-norm) — training/prefill path
# --------------------------------------------------------------------------

def init_attention(key, cfg: ModelConfig, dtype) -> Params:
    d, H, KV, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    p = {
        "wq": _dense_init(ks[0], (d, H, hd), d, dtype),
        "wk": _dense_init(ks[1], (d, KV, hd), d, dtype),
        "wv": _dense_init(ks[2], (d, KV, hd), d, dtype),
        "wo": _dense_init(ks[3], (H, hd, d), H * hd, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = init_rmsnorm(hd, dtype)
        p["k_norm"] = init_rmsnorm(hd, dtype)
    return p


def _softcap(logits: jnp.ndarray, cap: float) -> jnp.ndarray:
    if cap and cap > 0.0:
        return jnp.tanh(logits / cap) * cap
    return logits


def attention_mask(q_pos: jnp.ndarray, k_pos: jnp.ndarray, *,
                   is_local, window: int) -> jnp.ndarray:
    """Boolean (broadcast) mask: True = attend. q_pos (..., Sq), k_pos (..., Sk).

    ``is_local`` may be a traced scalar bool (gemma2 alternating layers under
    scan) — resolved with jnp.where so a single program serves both kinds.
    """
    q = q_pos[..., :, None]
    k = k_pos[..., None, :]
    causal = k <= q
    local = causal & (k > q - window)
    return jnp.where(is_local, local, causal)


def multi_head_attention(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                         positions: jnp.ndarray,
                         is_local=False,
                         use_pallas: bool = False,
                         return_kv: bool = False):
    """Full-sequence attention. x: (B, S, d); positions: (B, S).
    With return_kv, also returns the rope'd (k, v) for prefill cache fill."""
    B, S, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    q = constrain(q, "batch", None, "model", None)
    if cfg.qk_norm:
        q = apply_rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = apply_rmsnorm(p["k_norm"], k, cfg.norm_eps)
    sin, cos = rope_angles(positions, hd, cfg.rope_theta)
    sin_b, cos_b = _bcast_rope(sin, cos)
    q = apply_rope(q, sin_b, cos_b)
    k = apply_rope(k, sin_b, cos_b)

    if use_pallas:
        from repro.kernels.flash_attention import ops as fa_ops
        ctx = fa_ops.flash_attention(
            q, k, v, causal=True,
            window=cfg.sliding_window if bool(is_local) else 0,
            softcap=cfg.attn_logit_softcap,
        )
    else:
        ctx = sdpa(
            q, k, v,
            q_pos=positions, k_pos=positions,
            is_local=is_local, window=cfg.sliding_window,
            softcap=cfg.attn_logit_softcap,
        )
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(x.dtype))
    if return_kv:
        return out, (k, v)
    return out


QCHUNK_THRESHOLD = 4096     # q-chunk full-sequence attention above this Sq
QCHUNK = 1024               # query-block size for the chunked XLA path

# ---------------------------------------------------------------------------
# unroll mode: the dry-run traces with statically unrolled inner loops so
# XLA cost analysis (which counts while-loop bodies exactly once) reports
# honest per-step FLOPs/bytes. Production/tests keep lax.scan.
# ---------------------------------------------------------------------------
import contextlib

_UNROLL = False


def unroll_mode() -> bool:
    return _UNROLL


@contextlib.contextmanager
def unrolled(enable: bool = True):
    global _UNROLL
    old = _UNROLL
    _UNROLL = enable
    try:
        yield
    finally:
        _UNROLL = old


def maybe_scan(f, carry, xs):
    """lax.scan, or an unrolled python loop under `unrolled()` tracing."""
    if not _UNROLL:
        return lax.scan(f, carry, xs)
    n = jax.tree_util.tree_leaves(xs)[0].shape[0]
    ys = []
    for i in range(n):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = f(carry, xi)
        ys.append(y)
    ys = jax.tree.map(lambda *a: jnp.stack(a), *ys)
    return carry, ys


def sdpa_qchunked(q, k, v, *, q_pos, k_pos, is_local, window, softcap,
                  chunk: int = QCHUNK) -> jnp.ndarray:
    """Query-block-chunked attention: never materializes the full Sq×Sk logit
    matrix (the XLA-path analogue of the Pallas flash kernel's VMEM tiling —
    peak temp drops from O(Sq·Sk) to O(chunk·Sk) per head)."""
    B, Sq, H, hd = q.shape
    if Sq % chunk:
        return sdpa_reference(q, k, v, q_pos=q_pos, k_pos=k_pos,
                              is_local=is_local, window=window, softcap=softcap)
    nq = Sq // chunk
    qs = q.reshape(B, nq, chunk, H, hd).swapaxes(0, 1)        # (nq,B,c,H,hd)
    ps = q_pos.reshape(B, nq, chunk).swapaxes(0, 1)

    def body(_, xs):
        qc, pc = xs
        ctx = sdpa_reference(qc, k, v, q_pos=pc, k_pos=k_pos,
                             is_local=is_local, window=window, softcap=softcap)
        return None, ctx

    _, out = maybe_scan(body, None, (qs, ps))
    return out.swapaxes(0, 1).reshape(B, Sq, H, v.shape[-1])


def sdpa(q, k, v, *, q_pos, k_pos, is_local, window, softcap) -> jnp.ndarray:
    if q.shape[1] > QCHUNK_THRESHOLD:
        return sdpa_qchunked(q, k, v, q_pos=q_pos, k_pos=k_pos,
                             is_local=is_local, window=window, softcap=softcap)
    return sdpa_reference(q, k, v, q_pos=q_pos, k_pos=k_pos, is_local=is_local,
                          window=window, softcap=softcap)


def sdpa_reference(q, k, v, *, q_pos, k_pos, is_local, window, softcap) -> jnp.ndarray:
    """Masked GQA attention, fp32 softmax. q: (B,Sq,H,hd), k/v: (B,Sk,KV,hd).

    KV heads are expanded to the full H so the Sq×Sk logit tensor carries a
    clean (batch, model-on-heads) sharding — GQA head counts (8, 4, 2) are
    rarely divisible by the 16-wide model axis, but H always is here. The
    expansion costs O(B·Sk·H·hd) bytes, negligible against the logits."""
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    k = constrain(k, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    logits = jnp.einsum("bqhk,bshk->bhqs", q, k).astype(jnp.float32)
    logits = constrain(logits, "batch", "model", None, None)
    logits = logits / math.sqrt(hd)
    logits = _softcap(logits, softcap)
    mask = attention_mask(q_pos, k_pos, is_local=is_local, window=window)  # (B,Sq,Sk)
    logits = jnp.where(mask[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    ctx = jnp.einsum("bhqs,bshk->bqhk", probs, v)
    return constrain(ctx, "batch", None, "model", None)


# --------------------------------------------------------------------------
# Attention — single-token decode against a ring-buffer KV cache
# --------------------------------------------------------------------------

def init_kv_cache(cfg: ModelConfig, batch: int, cache_len: int, num_layers: int,
                  dtype) -> Params:
    KV, hd = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((num_layers, batch, cache_len, KV, hd), dtype),
        "v": jnp.zeros((num_layers, batch, cache_len, KV, hd), dtype),
        # absolute position stored per slot; -1 = empty
        "pos": jnp.full((num_layers, batch, cache_len), -1, jnp.int32),
    }


def decode_attention(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                     cache_k: jnp.ndarray, cache_v: jnp.ndarray,
                     cache_pos: jnp.ndarray, cur_pos: jnp.ndarray,
                     is_local=False) -> Tuple[jnp.ndarray, Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]]:
    """One-token decode. x: (B, 1, d); cache_k/v: (B, C, KV, hd);
    cache_pos: (B, C) absolute positions; cur_pos: (B,) int32.

    Returns (out (B,1,d), updated (k, v, pos)). Ring-buffer write at
    cur_pos % C, so a sliding-window cache uses C = window.
    """
    B, _, d = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    C = cache_k.shape[1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if cfg.qk_norm:
        q = apply_rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = apply_rmsnorm(p["k_norm"], k, cfg.norm_eps)
    sin, cos = rope_angles(cur_pos[:, None], hd, cfg.rope_theta)  # (B,1,half)
    sin_b, cos_b = _bcast_rope(sin, cos)
    q = apply_rope(q, sin_b, cos_b)
    k = apply_rope(k, sin_b, cos_b)

    slot = (cur_pos % C).astype(jnp.int32)                      # (B,)
    bidx = jnp.arange(B)
    new_k = cache_k.at[bidx, slot].set(k[:, 0].astype(cache_k.dtype))
    new_v = cache_v.at[bidx, slot].set(v[:, 0].astype(cache_v.dtype))
    new_pos = cache_pos.at[bidx, slot].set(cur_pos.astype(jnp.int32))

    G = H // KV
    valid = (new_pos >= 0) & (new_pos <= cur_pos[:, None])       # (B, C)
    window_ok = jnp.where(is_local, new_pos > cur_pos[:, None] - cfg.sliding_window, True)
    mask = valid & window_ok
    if cfg.decode_expand_kv:
        # hillclimbed decode: expand kv heads so logits shard heads over the
        # model axis (cache replicated over model — no per-layer all-reduce)
        kf = jnp.repeat(new_k.astype(q.dtype), G, axis=2)        # (B,C,H,hd)
        vf = jnp.repeat(new_v.astype(q.dtype), G, axis=2)
        qh = constrain(q[:, 0], "batch", "model", None)          # (B,H,hd)
        logits = jnp.einsum("bhk,bchk->bhc", qh, kf).astype(jnp.float32)
        logits = constrain(logits, "batch", "model", None)
        logits = _softcap(logits / math.sqrt(hd), cfg.attn_logit_softcap)
        logits = jnp.where(mask[:, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        ctx = jnp.einsum("bhc,bchk->bhk", probs, vf)[:, None]    # (B,1,H,hd)
    else:
        qg = q.reshape(B, KV, G, hd)                             # Sq==1 squeezed
        logits = jnp.einsum("bhgk,bchk->bhgc", qg, new_k.astype(q.dtype)).astype(jnp.float32)
        logits = _softcap(logits / math.sqrt(hd), cfg.attn_logit_softcap)
        logits = jnp.where(mask[:, None, None], logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        ctx = jnp.einsum("bhgc,bchk->bhgk", probs, new_v.astype(q.dtype))
        ctx = ctx.reshape(B, 1, H, hd)
    out = jnp.einsum("bshk,hkd->bsd", ctx, p["wo"].astype(x.dtype))
    return out, (new_k, new_v, new_pos)


# --------------------------------------------------------------------------
# MLA — DeepSeek multi-head latent attention
# --------------------------------------------------------------------------

def init_mla(key, cfg: ModelConfig, dtype) -> Params:
    m = cfg.mla
    d, H = cfg.d_model, cfg.num_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 8)
    return {
        "w_dq": _dense_init(ks[0], (d, m.q_lora_rank), d, dtype),
        "q_norm": init_rmsnorm(m.q_lora_rank, dtype),
        "w_uq": _dense_init(ks[1], (m.q_lora_rank, H, qk), m.q_lora_rank, dtype),
        "w_dkv": _dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_head_dim), d, dtype),
        "kv_norm": init_rmsnorm(m.kv_lora_rank, dtype),
        "w_uk": _dense_init(ks[3], (m.kv_lora_rank, H, m.qk_nope_head_dim), m.kv_lora_rank, dtype),
        "w_uv": _dense_init(ks[4], (m.kv_lora_rank, H, m.v_head_dim), m.kv_lora_rank, dtype),
        "wo": _dense_init(ks[5], (H, m.v_head_dim, d), H * m.v_head_dim, dtype),
    }


def mla_attention(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                  positions: jnp.ndarray, is_local=False,
                  return_kv: bool = False):
    """Training/prefill MLA (naive expanded form). x: (B, S, d).
    With return_kv, also returns the latent cache entries (ckv, k_rope)."""
    m = cfg.mla
    B, S, d = x.shape
    H = cfg.num_heads
    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(x.dtype))
    cq = apply_rmsnorm(p["q_norm"], cq, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(x.dtype))
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))
    ckv, k_rope = ckv_full[..., : m.kv_lora_rank], ckv_full[..., m.kv_lora_rank:]
    ckv = apply_rmsnorm(p["kv_norm"], ckv, cfg.norm_eps)
    k_nope = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uk"].astype(x.dtype))
    v = jnp.einsum("bsr,rhk->bshk", ckv, p["w_uv"].astype(x.dtype))

    sin, cos = rope_angles(positions, m.qk_rope_head_dim, cfg.rope_theta)
    sin_b, cos_b = _bcast_rope(sin, cos)
    q_rope = apply_rope(q_rope, sin_b, cos_b)
    k_rope = apply_rope(k_rope[:, :, None, :], sin_b, cos_b)     # (B,S,1,rope)

    # treat (nope ‖ rope) as one effective head dim and reuse the (q-chunked)
    # sdpa path — k_rope is shared across heads (broadcast as a 1-kv-head
    # suffix is wrong for GQA grouping, so concatenate explicitly).
    q_eff = jnp.concatenate([q_nope, q_rope], axis=-1)           # (B,S,H,nope+rope)
    k_eff = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (B, S, H, m.qk_rope_head_dim))], axis=-1)
    q_eff = constrain(q_eff, "batch", None, "model", None)
    k_eff = constrain(k_eff, "batch", None, "model", None)
    v = constrain(v, "batch", None, "model", None)
    dim_eff = m.qk_nope_head_dim + m.qk_rope_head_dim
    # sdpa scales by 1/sqrt(dim_eff) — matches MLA's scale over (nope+rope)
    ctx = sdpa(q_eff, k_eff, v, q_pos=positions, k_pos=positions,
               is_local=is_local, window=cfg.sliding_window, softcap=0.0)
    out = jnp.einsum("bqhk,hkd->bqd", ctx, p["wo"].astype(x.dtype))
    if return_kv:
        return out, (ckv, k_rope[:, :, 0, :])
    return out


def init_mla_cache(cfg: ModelConfig, batch: int, cache_len: int, num_layers: int, dtype) -> Params:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((num_layers, batch, cache_len, m.kv_lora_rank), dtype),
        "krope": jnp.zeros((num_layers, batch, cache_len, m.qk_rope_head_dim), dtype),
        "pos": jnp.full((num_layers, batch, cache_len), -1, jnp.int32),
    }


def mla_decode(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
               cache_ckv, cache_krope, cache_pos, cur_pos,
               is_local=False) -> Tuple[jnp.ndarray, Tuple]:
    """Absorbed-weight MLA decode: scores against the compressed cache —
    the latent cache (kv_lora + rope dims per token) is the MLA memory win.
    """
    m = cfg.mla
    B, _, d = x.shape
    H = cfg.num_heads
    C = cache_ckv.shape[1]
    cq = jnp.einsum("bsd,dr->bsr", x, p["w_dq"].astype(x.dtype))
    cq = apply_rmsnorm(p["q_norm"], cq, cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["w_uq"].astype(x.dtype))[:, 0]  # (B,H,qk)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]

    ckv_full = jnp.einsum("bsd,dr->bsr", x, p["w_dkv"].astype(x.dtype))[:, 0]
    ckv, k_rope = ckv_full[..., : m.kv_lora_rank], ckv_full[..., m.kv_lora_rank:]
    ckv = apply_rmsnorm(p["kv_norm"], ckv, cfg.norm_eps)

    sin, cos = rope_angles(cur_pos[:, None], m.qk_rope_head_dim, cfg.rope_theta)
    q_rope = apply_rope(q_rope, sin[:, 0][:, None, :], cos[:, 0][:, None, :])
    k_rope = apply_rope(k_rope[:, None, :], sin, cos)[:, 0]

    slot = (cur_pos % C).astype(jnp.int32)
    bidx = jnp.arange(B)
    new_ckv = cache_ckv.at[bidx, slot].set(ckv.astype(cache_ckv.dtype))
    new_krope = cache_krope.at[bidx, slot].set(k_rope.astype(cache_krope.dtype))
    new_pos = cache_pos.at[bidx, slot].set(cur_pos.astype(jnp.int32))

    # absorb: q_eff[b,h,r] = sum_k q_nope[b,h,k] * w_uk[r,h,k]
    q_eff = jnp.einsum("bhk,rhk->bhr", q_nope, p["w_uk"].astype(x.dtype))
    scale = 1.0 / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    logits = (
        jnp.einsum("bhr,bcr->bhc", q_eff, new_ckv.astype(x.dtype))
        + jnp.einsum("bhk,bck->bhc", q_rope, new_krope.astype(x.dtype))
    ).astype(jnp.float32) * scale
    valid = (new_pos >= 0) & (new_pos <= cur_pos[:, None])
    window_ok = jnp.where(is_local, new_pos > cur_pos[:, None] - cfg.sliding_window, True)
    logits = jnp.where((valid & window_ok)[:, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
    ctx = jnp.einsum("bhc,bcr->bhr", probs, new_ckv.astype(x.dtype))   # latent ctx
    out_h = jnp.einsum("bhr,rhk->bhk", ctx, p["w_uv"].astype(x.dtype))  # (B,H,v)
    out = jnp.einsum("bhk,hkd->bd", out_h, p["wo"].astype(x.dtype))[:, None]
    return out, (new_ckv, new_krope, new_pos)


# --------------------------------------------------------------------------
# SwiGLU MLP
# --------------------------------------------------------------------------

def init_mlp(key, d: int, d_ff: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "w_gate": _dense_init(ks[0], (d, d_ff), d, dtype),
        "w_up": _dense_init(ks[1], (d, d_ff), d, dtype),
        "w_down": _dense_init(ks[2], (d_ff, d), d_ff, dtype),
    }


def apply_mlp(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, p["w_up"].astype(x.dtype))
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, p["w_down"].astype(x.dtype))


# --------------------------------------------------------------------------
# Mixture of Experts
# --------------------------------------------------------------------------

def init_moe(key, cfg: ModelConfig, dtype) -> Params:
    mo = cfg.moe
    d, E, f = cfg.d_model, mo.num_experts, mo.d_ff_expert
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, E), d, jnp.float32),   # router in fp32
        "w_gate": _dense_init(ks[1], (E, d, f), d, dtype),
        "w_up": _dense_init(ks[2], (E, d, f), d, dtype),
        "w_down": _dense_init(ks[3], (E, f, d), f, dtype),
    }
    if mo.router == "sigmoid":
        p["router_bias"] = _zeros((E,), jnp.float32)            # ds-v3 aux-free bias
    if mo.num_shared_experts:
        p["shared"] = init_mlp(ks[4], d, mo.d_ff_shared * mo.num_shared_experts, dtype)
    return p


def _router_probs(p: Params, x2d: jnp.ndarray, mo: MoEConfig):
    """x2d: (T, d) -> (gates (T,k), idx (T,k), probs_full (T,E) fp32)."""
    logits = jnp.einsum("td,de->te", x2d.astype(jnp.float32), p["router"])
    if mo.router == "sigmoid":
        probs = jax.nn.sigmoid(logits)
        sel = probs + p["router_bias"][None, :]                 # bias affects selection only
        _, idx = lax.top_k(sel, mo.top_k)
        gates = jnp.take_along_axis(probs, idx, axis=-1)
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
        gates = gates * mo.routed_scaling
    else:
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = lax.top_k(probs, mo.top_k)
        gates = gates / (jnp.sum(gates, axis=-1, keepdims=True) + 1e-9)
    return gates, idx, probs


def moe_aux_loss(probs: jnp.ndarray, idx: jnp.ndarray, mo: MoEConfig) -> jnp.ndarray:
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    E = mo.num_experts
    T = probs.shape[0]
    counts = jnp.zeros((E,), jnp.float32)
    one_hot = jax.nn.one_hot(idx, E, dtype=jnp.float32)          # (T, k, E)
    counts = one_hot.sum(axis=(0, 1))
    f = counts / (T * mo.top_k)
    P = probs.mean(axis=0)
    return E * jnp.sum(f * P)


def apply_moe_dense(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dense (all-experts) path for tiny smoke configs: every token through
    every expert, weighted by the (top-k masked) gate. O(T·E·d·f) FLOPs."""
    mo = cfg.moe
    B, S, d = x.shape
    x2d = x.reshape(-1, d)
    gates, idx, probs = _router_probs(p, x2d, mo)
    E = mo.num_experts
    dense_gates = jnp.zeros((x2d.shape[0], E), jnp.float32)
    dense_gates = dense_gates.at[jnp.arange(x2d.shape[0])[:, None], idx].add(gates)
    g = jnp.einsum("td,edf->tef", x2d, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("td,edf->tef", x2d, p["w_up"].astype(x.dtype))
    y = jnp.einsum("tef,efd->ted", jax.nn.silu(g) * u, p["w_down"].astype(x.dtype))
    out = jnp.einsum("ted,te->td", y.astype(jnp.float32), dense_gates).astype(x.dtype)
    if mo.num_shared_experts:
        out = out + apply_mlp(p["shared"], x2d)
    return out.reshape(B, S, d), moe_aux_loss(probs, idx, mo)


def apply_moe_gspmd(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-based dispatch via scatter/gather (no one-hot matmuls, so
    cost_analysis FLOPs stay honest ≈ active-expert FLOPs × capacity_factor).

    Runs under plain jit; GSPMD partitions the (E, C, d) buffers over the
    mesh. Tokens above capacity are dropped (standard Switch semantics).
    """
    mo = cfg.moe
    B, S, d = x.shape
    T = B * S
    E, k = mo.num_experts, mo.top_k
    x2d = x.reshape(T, d)
    gates, idx, probs = _router_probs(p, x2d, mo)

    cap = max(int(mo.capacity_factor * T * k / E), 1)

    # position of each (token, slot) within its expert queue, via a stable
    # sort by expert id (earliest-token capacity priority). NOTE: a (T·k, E)
    # one-hot cumsum would lower to an O((T·k)²·E) reduce-window — the sort
    # is both honest in cost analysis and cheaper on hardware.
    flat_e = idx.reshape(-1)                                     # (T*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))           # first row per expert
    pos_sorted = jnp.arange(T * k) - starts[sorted_e]
    pos = jnp.zeros((T * k,), jnp.int32).at[order].set(pos_sorted.astype(jnp.int32))
    keep = pos < cap
    slot = jnp.where(keep, pos, cap)                             # overflow slot

    src = jnp.repeat(x2d, k, axis=0)                             # (T*k, d)
    buf = jnp.zeros((E, cap + 1, d), x.dtype)
    buf = buf.at[flat_e, slot].set(src)                          # dests unique
    ebuf = constrain(buf[:, :cap], "model", None, None)          # expert parallel

    g = jnp.einsum("ecd,edf->ecf", ebuf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", ebuf, p["w_up"].astype(x.dtype))
    g = constrain(g, "model", None, None)
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, p["w_down"].astype(x.dtype))
    y = constrain(y, "model", None, None)

    y_pad = jnp.concatenate([y, jnp.zeros((E, 1, d), y.dtype)], axis=1)
    back = y_pad[flat_e, slot]                                   # (T*k, d)
    back = constrain(back, "batch", None)
    w = (gates.reshape(-1) * keep.astype(jnp.float32)).astype(x.dtype)
    out = jnp.sum((back * w[:, None]).reshape(T, k, d), axis=1)
    if mo.num_shared_experts:
        out = out + apply_mlp(p["shared"], x2d)
    return out.reshape(B, S, d), moe_aux_loss(probs, idx, mo)


def apply_moe(p: Params, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    impl = cfg.moe.impl
    if impl == "dense":
        return apply_moe_dense(p, x, cfg)
    if impl == "ep":
        from repro.models.moe_ep import apply_moe_ep
        return apply_moe_ep(p, x, cfg)
    return apply_moe_gspmd(p, x, cfg)


# --------------------------------------------------------------------------
# RWKV6 (Finch): time-mix with data-dependent decay + channel mix
# --------------------------------------------------------------------------

def init_rwkv6(key, cfg: ModelConfig, dtype) -> Params:
    d = cfg.d_model
    H, hd = cfg.num_heads, cfg.ssm.head_dim
    inner = H * hd
    lora = max(32, d // 16)
    ks = jax.random.split(key, 12)
    return {
        # data-dependent token-shift lerp (5 targets: r,k,v,w,g)
        "mix_base": (jax.random.uniform(ks[0], (5, d), jnp.float32) * 0.5).astype(dtype),
        "mix_lora_a": _dense_init(ks[1], (d, 5, lora // 2), d, dtype),
        "mix_lora_b": _dense_init(ks[2], (5, lora // 2, d), lora, dtype),
        "w_r": _dense_init(ks[3], (d, H, hd), d, dtype),
        "w_k": _dense_init(ks[4], (d, H, hd), d, dtype),
        "w_v": _dense_init(ks[5], (d, H, hd), d, dtype),
        "w_g": _dense_init(ks[6], (d, inner), d, dtype),
        "w_o": _dense_init(ks[7], (H, hd, d), inner, dtype),
        # decay: w_t = exp(-exp(decay_base + lora(x)))
        "decay_base": (jax.random.normal(ks[8], (H, hd), jnp.float32) * 0.3 - 1.0).astype(jnp.float32),
        "decay_lora_a": _dense_init(ks[9], (d, lora), d, dtype),
        "decay_lora_b": _dense_init(ks[10], (lora, H, hd), lora, dtype),
        "bonus": (jax.random.normal(ks[11], (H, hd), jnp.float32) * 0.3).astype(jnp.float32),
        "ln_out": init_rmsnorm(inner, dtype),
    }


def _rwkv6_rkvwg(p: Params, x: jnp.ndarray, x_prev: jnp.ndarray, cfg: ModelConfig):
    """Token-shift data-dependent mixing -> (r, k, v, w(decay, fp32), g)."""
    H, hd = cfg.num_heads, cfg.ssm.head_dim
    shifted = x_prev
    # ddlerp: mix_i = x + (shifted - x) * (base_i + lora_i(x))
    lora_in = jnp.einsum("...d,dml->...ml", x, p["mix_lora_a"].astype(x.dtype))
    lora = jnp.einsum("...ml,mld->...md", jnp.tanh(lora_in), p["mix_lora_b"].astype(x.dtype))
    mixes = x[..., None, :] + (shifted - x)[..., None, :] * (
        p["mix_base"].astype(x.dtype) + lora
    )                                                            # (..., 5, d)
    mixes = constrain(mixes, *(["batch"] + [None] * (mixes.ndim - 2) + ["model"]))
    xr, xk, xv, xw, xg = [mixes[..., i, :] for i in range(5)]
    r = jnp.einsum("...d,dhk->...hk", xr, p["w_r"].astype(x.dtype))
    k = jnp.einsum("...d,dhk->...hk", xk, p["w_k"].astype(x.dtype))
    v = jnp.einsum("...d,dhk->...hk", xv, p["w_v"].astype(x.dtype))
    dl = jnp.einsum("...d,dl->...l", xw, p["decay_lora_a"].astype(x.dtype))
    dw = jnp.einsum("...l,lhk->...hk", jnp.tanh(dl), p["decay_lora_b"].astype(x.dtype))
    # Clip so per-step log-decay >= -e^1.6 ~= -4.95: keeps the chunked
    # factored form (k * exp(-cumdecay)) inside fp32 range for chunk<=16
    # while per-step retention down to e^-4.95 ~= 0.007 covers the practical
    # RWKV6 decay regime (see kernels/rwkv6/ref.py stability note).
    log_w = -jnp.exp(jnp.clip(p["decay_base"] + dw.astype(jnp.float32), -8.0, 1.6))
    g = jax.nn.silu(jnp.einsum("...d,di->...i", xg, p["w_g"].astype(x.dtype)))
    return r, k, v, log_w, g


def rwkv6_timemix(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                  use_pallas: bool = False, return_state: bool = False):
    """Full-sequence RWKV6 time mix. x: (B, S, d). With return_state, also
    returns the final recurrent wkv state (B, H, K, V) for prefill."""
    B, S, d = x.shape
    H, hd = cfg.num_heads, cfg.ssm.head_dim
    x_prev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r, k, v, log_w, g = _rwkv6_rkvwg(p, x, x_prev, cfg)
    from repro.kernels.rwkv6 import ref as rwkv_ref
    if use_pallas and not return_state:
        from repro.kernels.rwkv6 import ops as rwkv_ops
        o = rwkv_ops.wkv6(r, k, v, log_w, p["bonus"], chunk=cfg.ssm.chunk)
        state = None
    else:
        res = rwkv_ref.wkv6_chunked(r, k, v, log_w, p["bonus"],
                                    chunk=cfg.ssm.chunk,
                                    return_state=return_state,
                                    shard=cfg.ssm.shard)
        o, state = res if return_state else (res, None)
    o = o.reshape(B, S, H * hd).astype(x.dtype)
    o = apply_rmsnorm(p["ln_out"], o, cfg.norm_eps) * g
    out = jnp.einsum("bshk,hkd->bsd", o.reshape(B, S, H, hd), p["w_o"].astype(x.dtype))
    if return_state:
        return out, state
    return out


def init_rwkv6_channelmix(key, cfg: ModelConfig, dtype) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "mix_k": (jax.random.uniform(ks[0], (d,), jnp.float32) * 0.5).astype(dtype),
        "w_k": _dense_init(ks[0], (d, f), d, dtype),
        "w_v": _dense_init(ks[1], (f, d), f, dtype),
        "w_r": _dense_init(ks[2], (d, d), d, dtype),
    }


def rwkv6_channelmix(p: Params, x: jnp.ndarray, x_prev: jnp.ndarray) -> jnp.ndarray:
    xk = x + (x_prev - x) * p["mix_k"].astype(x.dtype)
    k = jnp.einsum("...d,df->...f", xk, p["w_k"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    kv = jnp.einsum("...f,fd->...d", k, p["w_v"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("...d,de->...e", xk, p["w_r"].astype(x.dtype)))
    return r * kv


def rwkv6_decode_step(p_tm: Params, p_cm: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                      state: jnp.ndarray, x_prev_att: jnp.ndarray,
                      x_prev_ffn: jnp.ndarray, norm_att: Params, norm_ffn: Params):
    """Single-token RWKV6 block step. x: (B, 1, d). state: (B, H, hd, hd)."""
    B, _, d = x.shape
    H, hd = cfg.num_heads, cfg.ssm.head_dim
    xa = apply_rmsnorm(norm_att, x, cfg.norm_eps)[:, 0]          # (B, d)
    r, k, v, log_w, g = _rwkv6_rkvwg(p_tm, xa, x_prev_att, cfg)
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    u = p_tm["bonus"]
    # o = r · (S + u ⊙ k vᵀ); S' = diag(w) S + k vᵀ
    kv = jnp.einsum("bhk,bhv->bhkv", kf, vf)
    o = jnp.einsum("bhk,bhkv->bhv", rf, state + u[None, :, :, None] * kv)
    new_state = jnp.exp(log_w)[..., None] * state + kv
    o = o.reshape(B, H * hd).astype(x.dtype)
    o = apply_rmsnorm(p_tm["ln_out"], o, cfg.norm_eps) * g
    att_out = jnp.einsum("bhk,hkd->bd", o.reshape(B, H, hd), p_tm["w_o"].astype(x.dtype))
    h = x[:, 0] + att_out
    xf = apply_rmsnorm(norm_ffn, h[:, None], cfg.norm_eps)[:, 0]
    ffn_out = rwkv6_channelmix(p_cm, xf, x_prev_ffn)
    # fp32 token-shift states promote the residual — cast back so the layer
    # scan carry keeps the compute dtype
    out = (h + ffn_out).astype(x.dtype)[:, None]
    return out, new_state, xa.astype(jnp.float32), xf.astype(jnp.float32)


# --------------------------------------------------------------------------
# Mamba2 (SSD)
# --------------------------------------------------------------------------

def init_mamba2(key, cfg: ModelConfig, dtype) -> Params:
    s = cfg.ssm
    d = cfg.d_model
    inner = s.expand * d
    H = inner // s.head_dim
    N = s.state_dim
    conv_ch = inner + 2 * N
    ks = jax.random.split(key, 6)
    return {
        "w_in": _dense_init(ks[0], (d, 2 * inner + 2 * N + H), d, dtype),
        "conv_w": (jax.random.normal(ks[1], (s.conv_dim, conv_ch), jnp.float32) * 0.2).astype(dtype),
        "conv_b": _zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": _ones((H,), jnp.float32),
        "dt_bias": (jax.random.uniform(ks[2], (H,), jnp.float32) * 2.0 - 4.0).astype(jnp.float32),
        "gate_norm": init_rmsnorm(inner, dtype),
        "w_out": _dense_init(ks[3], (inner, d), inner, dtype),
    }


def mamba2_forward(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                   return_state: bool = False):
    """Full-sequence Mamba2 (chunked SSD). x: (B, S, d). With return_state,
    also returns (conv_window (B, K-1, C), ssm_state (B, H, N, P))."""
    s = cfg.ssm
    B, S, d = x.shape
    inner = s.expand * d
    H = inner // s.head_dim
    N = s.state_dim
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))
    proj = constrain(proj, "batch", None, "model")
    z, xin, Bc, Cc, dt = jnp.split(proj, [inner, 2 * inner, 2 * inner + N, 2 * inner + 2 * N], axis=-1)
    conv_raw = jnp.concatenate([xin, Bc, Cc], axis=-1)
    conv_raw = constrain(conv_raw, "batch", None, "model")
    conv_in = _causal_conv1d(conv_raw, p["conv_w"].astype(x.dtype), p["conv_b"].astype(x.dtype))
    conv_in = jax.nn.silu(conv_in)
    xin, Bc, Cc = jnp.split(conv_in, [inner, inner + N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"])                                      # (H,)
    xh = xin.reshape(B, S, H, s.head_dim)
    y, ssm_state = ssd_chunked(xh, dt, A, Bc, Cc, chunk=s.chunk,
                               return_state=True)                 # (B,S,H,hd)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, inner).astype(x.dtype)
    y = apply_rmsnorm(p["gate_norm"], y, cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(x.dtype))
    if return_state:
        K = s.conv_dim
        pad = jnp.pad(conv_raw, ((0, 0), (K - 1, 0), (0, 0)))
        conv_window = pad[:, -(K - 1):].astype(jnp.float32)
        return out, (conv_window, ssm_state)
    return out


def _causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv. x: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    xpad = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):                                            # K is tiny (4)
        out = out + xpad[:, i : i + x.shape[1]] * w[i]
    return out + b


def linear_recurrence_pscan(a, b, extra_dims: int = 1):
    """Inclusive prefix states of s_i = a_i ⊙ s_{i-1} + b_i along axis 1 via
    associative scan (log-depth, fully materialized — TPU-parallel and
    honestly counted by HLO cost analysis, unlike a while-loop scan).

    a: (G, n, K); b: (G, n, K, *extra). Returns inclusive states like b."""
    def comb(l, r):
        al, bl = l
        ar, br = r
        arx = ar.reshape(ar.shape + (1,) * extra_dims)
        return al * ar, bl * arx + br

    _, incl = lax.associative_scan(comb, (a, b), axis=1)
    return incl


def _prev_states(a, b, extra_dims: int = 1):
    """(exclusive-prefix states, final state) for the recurrence above."""
    incl = linear_recurrence_pscan(a, b, extra_dims)
    prev = jnp.concatenate(
        [jnp.zeros_like(incl[:, :1]), incl[:, :-1]], axis=1)
    return prev, incl[:, -1]


def ssd_chunked(xh, dt, A, Bc, Cc, *, chunk: int, return_state: bool = False):
    """Chunked state-space-dual scan (Mamba2).

    xh: (B,S,H,P); dt: (B,S,H) fp32; A: (H,) fp32; Bc/Cc: (B,S,N).
    Returns fp32 (B,S,H,P) (+ final state (B,H,N,P) if return_state).
    Scalar-per-head decay -> (L,L) pairwise matrices.
    """
    B, S0, H, P = xh.shape
    N = Bc.shape[-1]
    L = min(chunk, S0)
    pad = (-S0) % L
    if pad:
        # dt = 0 -> unit decay and zero input contribution at padded steps
        xh = jnp.pad(xh, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    S = S0 + pad
    nc = S // L
    xb = constrain(xh.reshape(B, nc, L, H, P).astype(jnp.float32),
                   "batch", None, None, "model", None)
    dtb = constrain(dt.reshape(B, nc, L, H), "batch", None, None, "model")
    Bb = Bc.reshape(B, nc, L, N).astype(jnp.float32)
    Cb = Cc.reshape(B, nc, L, N).astype(jnp.float32)

    da = dtb * A[None, None, None, :]                             # (B,nc,L,H) log-decay per step
    cum = jnp.cumsum(da, axis=2)                                  # inclusive
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # (B,nc,Lq,Lk,H)
    Lq = jnp.arange(L)
    causal = (Lq[:, None] >= Lq[None, :])[None, None, :, :, None]
    # mask in log space BEFORE exp: the upper triangle has positive log-decay
    # sums that would overflow fp32.
    seg = jnp.exp(jnp.where(causal, diff, -1e30))

    # intra-chunk: y[t] = sum_{i<=t} C_t·B_i seg[t,i] dt_i x_i
    cb = jnp.einsum("bclN,bcmN->bclm", Cb, Bb)                    # (B,nc,Lq,Lk)
    scores = cb[..., None] * seg                                  # (B,nc,Lq,Lk,H)
    y_intra = jnp.einsum("bclmh,bcmh,bcmhp->bclhp", scores, dtb, xb)

    # chunk-final states: S_c = sum_i exp(cum_L - cum_i) dt_i B_i x_i^T
    decay_to_end = jnp.exp(cum[:, :, -1:, :] - cum)               # (B,nc,L,H)
    state_c = jnp.einsum("bclh,bclh,bclN,bclhp->bchNp",
                         decay_to_end, dtb, Bb, xb)               # per-chunk contribution

    # inter-chunk recurrence over chunk index (associative scan, log depth)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                       # (B,nc,H)
    prev_states, final_state = _prev_states(chunk_decay, state_c, extra_dims=2)

    # inter-chunk output: y[t] += C_t · (decay_from_start[t] * prev_state)
    decay_from_start = jnp.exp(cum)                               # (B,nc,L,H)
    y_inter = jnp.einsum("bclN,bclh,bchNp->bclhp", Cb, decay_from_start, prev_states)
    y = (y_intra + y_inter).reshape(B, S, H, P)[:, :S0]
    if return_state:
        return y, final_state
    return y


def init_mamba2_cache(cfg: ModelConfig, batch: int, num_layers: int) -> Params:
    s = cfg.ssm
    inner = s.expand * cfg.d_model
    H = inner // s.head_dim
    N = s.state_dim
    conv_ch = inner + 2 * N
    return {
        "conv": jnp.zeros((num_layers, batch, s.conv_dim - 1, conv_ch), jnp.float32),
        "ssm": jnp.zeros((num_layers, batch, H, N, s.head_dim), jnp.float32),
    }


def mamba2_decode_step(p: Params, x: jnp.ndarray, cfg: ModelConfig, *,
                       conv_state: jnp.ndarray, ssm_state: jnp.ndarray):
    """Single-token Mamba2 step. x: (B,1,d); conv_state: (B,K-1,C);
    ssm_state: (B,H,N,P)."""
    s = cfg.ssm
    B, _, d = x.shape
    inner = s.expand * d
    H = inner // s.head_dim
    N = s.state_dim
    proj = jnp.einsum("bsd,de->bse", x, p["w_in"].astype(x.dtype))[:, 0]
    z, xin, Bc, Cc, dt = jnp.split(proj, [inner, 2 * inner, 2 * inner + N, 2 * inner + 2 * N], axis=-1)
    conv_in = jnp.concatenate([xin, Bc, Cc], axis=-1)             # (B, C)
    window = jnp.concatenate([conv_state, conv_in[:, None].astype(jnp.float32)], axis=1)  # (B,K,C)
    w = p["conv_w"].astype(jnp.float32)
    conv_out = jnp.einsum("bkc,kc->bc", window, w) + p["conv_b"].astype(jnp.float32)
    conv_out = jax.nn.silu(conv_out).astype(x.dtype)
    new_conv_state = window[:, 1:]
    xin, Bc, Cc = jnp.split(conv_out, [inner, inner + N], axis=-1)
    dtf = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    A = -jnp.exp(p["A_log"])
    decay = jnp.exp(dtf * A[None, :])                             # (B,H)
    xhead = xin.reshape(B, H, s.head_dim).astype(jnp.float32)
    dBx = jnp.einsum("bh,bN,bhp->bhNp", dtf, Bc.astype(jnp.float32), xhead)
    new_ssm = ssm_state * decay[..., None, None] + dBx
    y = jnp.einsum("bN,bhNp->bhp", Cc.astype(jnp.float32), new_ssm)
    y = y + p["D"][None, :, None] * xhead
    y = y.reshape(B, inner).astype(x.dtype)
    y = apply_rmsnorm(p["gate_norm"], y[:, None], cfg.norm_eps)[:, 0] * jax.nn.silu(z)
    out = jnp.einsum("bi,id->bd", y, p["w_out"].astype(x.dtype))
    return out[:, None], new_conv_state, new_ssm
