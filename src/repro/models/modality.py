"""Modality-frontend STUBS (the one sanctioned carve-out, see DESIGN.md §8).

musicgen-large : EnCodec conditioning frames  -> (B, prefix_len, d_model)
chameleon-34b  : ViT/VQ patch embeddings      -> (B, prefix_len, d_model)

``synthetic_prefix`` produces statistically plausible stand-ins (unit-norm
rows with smooth temporal/spatial correlation) for smoke tests and the
end-to-end examples; ``prefix_spec`` produces the ShapeDtypeStruct used by
the dry-run input_specs().
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def prefix_spec(cfg: ModelConfig, batch: int, dtype=jnp.bfloat16):
    assert cfg.prefix_frontend
    return jax.ShapeDtypeStruct((batch, cfg.prefix_len, cfg.d_model), dtype)


def synthetic_prefix(key, cfg: ModelConfig, batch: int, dtype=jnp.float32):
    """Smoothly correlated unit-variance embeddings: white noise passed
    through a causal EMA over the frame/patch axis."""
    assert cfg.prefix_frontend
    noise = jax.random.normal(key, (batch, cfg.prefix_len, cfg.d_model), jnp.float32)

    def ema(carry, x):
        h = 0.7 * carry + 0.3 * x
        return h, h

    _, smooth = jax.lax.scan(ema, jnp.zeros((batch, cfg.d_model)), noise.swapaxes(0, 1))
    smooth = smooth.swapaxes(0, 1)
    smooth = smooth / (jnp.std(smooth, axis=-1, keepdims=True) + 1e-6)
    return smooth.astype(dtype)
