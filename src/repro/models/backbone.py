"""Backbone LM: scan-over-layers transformer covering all assigned families.

Families and their block stacks:
  dense / audio / vlm : uniform [attn + SwiGLU] stack (GQA, sliding window,
                        softcap, qk-norm per config)
  moe                 : [attn + MoE] stack; deepseek additionally has
                        `first_k_dense` leading dense layers, MLA attention,
                        and an MTP head
  ssm (rwkv6)         : [time-mix + channel-mix] stack
  hybrid (zamba2)     : rounds of `hybrid_period` Mamba2 blocks followed by
                        ONE weight-shared attention+MLP block, plus trailing
                        Mamba2 blocks

All stacks are jax.lax.scan over stacked parameters (keeps HLO size and
compile time flat in depth — essential for the 61-layer deepseek dry-run),
with optional jax.checkpoint (remat) on the block body.

Modality frontends (audio/vlm) are prefix stubs: precomputed embeddings
(B, P, d_model) are layer-normed and prepended to the token embeddings; the
loss masks prefix positions out.
"""
from __future__ import annotations

import functools
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.shardingx.constrain import constrain

Params = Dict[str, Any]


# ===========================================================================
# init
# ===========================================================================

def _init_dense_block(key, cfg: ModelConfig, dtype, *, moe_layer: bool) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {"ln_attn": L.init_rmsnorm(cfg.d_model, dtype),
                 "ln_mlp": L.init_rmsnorm(cfg.d_model, dtype)}
    if cfg.mla is not None:
        p["attn"] = L.init_mla(ks[0], cfg, dtype)
    else:
        p["attn"] = L.init_attention(ks[0], cfg, dtype)
    if moe_layer:
        p["moe"] = L.init_moe(ks[1], cfg, dtype)
    else:
        p["mlp"] = L.init_mlp(ks[1], cfg.d_model, cfg.d_ff, dtype)
    if cfg.post_block_norm:
        p["ln_post_attn"] = L.init_rmsnorm(cfg.d_model, dtype)
        p["ln_post_mlp"] = L.init_rmsnorm(cfg.d_model, dtype)
    return p


def _init_rwkv_block(key, cfg: ModelConfig, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "ln_att": L.init_rmsnorm(cfg.d_model, dtype),
        "ln_ffn": L.init_rmsnorm(cfg.d_model, dtype),
        "tm": L.init_rwkv6(ks[0], cfg, dtype),
        "cm": L.init_rwkv6_channelmix(ks[1], cfg, dtype),
    }


def _init_mamba_block(key, cfg: ModelConfig, dtype) -> Params:
    return {
        "ln": L.init_rmsnorm(cfg.d_model, dtype),
        "mamba": L.init_mamba2(key, cfg, dtype),
    }


def _stacked(init_fn, key, n: int):
    keys = jax.random.split(key, max(n, 1))
    return jax.vmap(init_fn)(keys) if n > 0 else None


def init_params(cfg: ModelConfig, key, param_dtype=jnp.float32) -> Params:
    dtype = jnp.dtype(param_dtype)
    k_embed, k_stack, k_extra, k_head, k_mtp = jax.random.split(key, 5)
    params: Params = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab_size, cfg.d_model), jnp.float32)
                  * 0.02).astype(dtype),
        "ln_final": L.init_rmsnorm(cfg.d_model, dtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L._dense_init(k_head, (cfg.d_model, cfg.vocab_size),
                                          cfg.d_model, dtype)
    if cfg.prefix_frontend:
        params["ln_prefix"] = L.init_rmsnorm(cfg.d_model, dtype)

    fam = cfg.family
    if fam in ("dense", "audio", "vlm"):
        params["layers"] = _stacked(
            lambda k: _init_dense_block(k, cfg, dtype, moe_layer=False),
            k_stack, cfg.num_layers)
    elif fam == "moe":
        n_moe = cfg.num_layers - cfg.first_k_dense
        if cfg.first_k_dense:
            params["dense_layers"] = _stacked(
                lambda k: _init_dense_block(k, cfg, dtype, moe_layer=False),
                k_extra, cfg.first_k_dense)
        params["layers"] = _stacked(
            lambda k: _init_dense_block(k, cfg, dtype, moe_layer=True),
            k_stack, n_moe)
        if cfg.mtp_depth:
            km1, km2 = jax.random.split(k_mtp)
            params["mtp"] = {
                "proj": L._dense_init(km1, (2 * cfg.d_model, cfg.d_model),
                                      2 * cfg.d_model, dtype),
                "ln_h": L.init_rmsnorm(cfg.d_model, dtype),
                "ln_e": L.init_rmsnorm(cfg.d_model, dtype),
                "block": _init_dense_block(km2, cfg, dtype, moe_layer=False),
            }
    elif fam == "ssm":
        params["layers"] = _stacked(lambda k: _init_rwkv_block(k, cfg, dtype),
                                    k_stack, cfg.num_layers)
    elif fam == "hybrid":
        rounds, trailing = _hybrid_split(cfg)
        params["layers"] = _stacked(lambda k: _init_mamba_block(k, cfg, dtype),
                                    k_stack, rounds * cfg.hybrid_period)
        if trailing:
            params["tail_layers"] = _stacked(
                lambda k: _init_mamba_block(k, cfg, dtype), k_extra, trailing)
        ks1, ks2 = jax.random.split(k_head if cfg.tie_embeddings else k_mtp)
        params["shared_block"] = _init_dense_block(ks1, cfg, dtype, moe_layer=False)
    else:
        raise ValueError(f"unknown family {fam}")
    return params


def _hybrid_split(cfg: ModelConfig) -> Tuple[int, int]:
    rounds = cfg.num_layers // cfg.hybrid_period
    trailing = cfg.num_layers - rounds * cfg.hybrid_period
    return rounds, trailing


# ===========================================================================
# forward (train / prefill)
# ===========================================================================

def _local_flags(cfg: ModelConfig, n: int) -> jnp.ndarray:
    if cfg.attn_variant == "sliding":
        return jnp.ones((n,), bool)
    if cfg.attn_variant == "alternating":
        return (jnp.arange(n) % 2) == 0
    return jnp.zeros((n,), bool)


def _dense_block_apply(lp: Params, x, cfg: ModelConfig, *, positions,
                       is_local, use_pallas: bool, moe_layer: bool):
    x = constrain(x, "batch", None, None)
    h = L.apply_rmsnorm(lp["ln_attn"], x, cfg.norm_eps)
    if cfg.mla is not None:
        attn = L.mla_attention(lp["attn"], h, cfg, positions=positions,
                               is_local=is_local)
    else:
        attn = L.multi_head_attention(lp["attn"], h, cfg, positions=positions,
                                      is_local=is_local, use_pallas=use_pallas)
    if cfg.post_block_norm:
        attn = L.apply_rmsnorm(lp["ln_post_attn"], attn, cfg.norm_eps)
    x = x + attn
    h = L.apply_rmsnorm(lp["ln_mlp"], x, cfg.norm_eps)
    aux = jnp.zeros((), jnp.float32)
    if moe_layer:
        out, aux = L.apply_moe(lp["moe"], h, cfg)
    else:
        out = L.apply_mlp(lp["mlp"], h)
    if cfg.post_block_norm:
        out = L.apply_rmsnorm(lp["ln_post_mlp"], out, cfg.norm_eps)
    return x + out, aux


def _rwkv_block_apply(lp: Params, x, cfg: ModelConfig, *, use_pallas: bool):
    x = constrain(x, "batch", None, None)
    h = L.apply_rmsnorm(lp["ln_att"], x, cfg.norm_eps)
    x = x + _timemix_full(lp["tm"], h, cfg, use_pallas)
    h = L.apply_rmsnorm(lp["ln_ffn"], x, cfg.norm_eps)
    h_prev = jnp.pad(h, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    return x + L.rwkv6_channelmix(lp["cm"], h, h_prev)


def _timemix_full(tm, h, cfg, use_pallas):
    return L.rwkv6_timemix(tm, h, cfg, use_pallas=use_pallas)


def _mamba_block_apply(lp: Params, x, cfg: ModelConfig):
    x = constrain(x, "batch", None, None)
    h = L.apply_rmsnorm(lp["ln"], x, cfg.norm_eps)
    return x + L.mamba2_forward(lp["mamba"], h, cfg)


def _scan_stack(body, x, stacked, flags=None, remat: bool = True):
    """Scan `body(x, layer_params, flag) -> (x, aux)` over stacked params —
    statically unrolled under layers.unrolled() (dry-run accounting)."""
    def f(carry, xs):
        lp, flag = xs
        out, aux = body(carry, lp, flag)
        return out, aux

    if remat:
        f = jax.checkpoint(f, prevent_cse=L.unroll_mode())
    n = jax.tree_util.tree_leaves(stacked)[0].shape[0]
    if flags is None:
        flags = jnp.zeros((n,), bool)
    x, auxs = L.maybe_scan(f, x, (stacked, flags))
    return x, jnp.sum(auxs)


def embed_inputs(params: Params, tokens, cfg: ModelConfig, *,
                 prefix_embeds=None):
    """-> (x (B, P+S, d), positions (B, P+S), loss_mask (B, P+S))."""
    B, S = tokens.shape
    x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.scale_embeddings:
        x = x * math.sqrt(cfg.d_model)
    loss_mask = jnp.ones((B, S), bool)
    if cfg.prefix_frontend:
        assert prefix_embeds is not None, f"{cfg.name} requires prefix_embeds"
        pe = L.apply_rmsnorm(params["ln_prefix"], prefix_embeds.astype(x.dtype),
                             cfg.norm_eps)
        x = jnp.concatenate([pe, x], axis=1)
        loss_mask = jnp.concatenate(
            [jnp.zeros((B, pe.shape[1]), bool), loss_mask], axis=1)
    T = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    return x, positions, loss_mask


def forward(params: Params, tokens, cfg: ModelConfig, *, prefix_embeds=None,
            use_pallas: bool = False, remat: bool = True,
            compute_dtype=jnp.bfloat16, return_logits: bool = True):
    """-> (logits (B, T, V) fp32 | None, hidden (B, T, d), aux)."""
    x, positions, loss_mask = embed_inputs(params, tokens, cfg,
                                           prefix_embeds=prefix_embeds)
    x = x.astype(compute_dtype)
    fam = cfg.family
    aux_total = jnp.zeros((), jnp.float32)

    if fam in ("dense", "audio", "vlm", "moe"):
        if fam == "moe" and cfg.first_k_dense:
            def dense_body(h, lp, flag):
                return _dense_block_apply(lp, h, cfg, positions=positions,
                                          is_local=flag, use_pallas=use_pallas,
                                          moe_layer=False)
            x, _ = _scan_stack(dense_body, x, params["dense_layers"],
                               flags=_local_flags(cfg, cfg.first_k_dense),
                               remat=remat)

        moe_layer = fam == "moe"
        n_main = jax.tree_util.tree_leaves(params["layers"])[0].shape[0]

        def body(h, lp, flag):
            return _dense_block_apply(lp, h, cfg, positions=positions,
                                      is_local=flag, use_pallas=use_pallas,
                                      moe_layer=moe_layer)
        x, aux_total = _scan_stack(body, x, params["layers"],
                                   flags=_local_flags(cfg, n_main), remat=remat)

    elif fam == "ssm":
        def body(h, lp, flag):
            return _rwkv_block_apply(lp, h, cfg, use_pallas=use_pallas), jnp.zeros((), jnp.float32)
        x, _ = _scan_stack(body, x, params["layers"], remat=remat)

    elif fam == "hybrid":
        rounds, trailing = _hybrid_split(cfg)
        per = cfg.hybrid_period
        stacked = jax.tree.map(
            lambda a: a.reshape((rounds, per) + a.shape[1:]), params["layers"])

        def round_body(h, round_params, flag):
            def inner(hh, lp, _):
                return _mamba_block_apply(lp, hh, cfg), jnp.zeros((), jnp.float32)
            h, _ = _scan_stack(inner, h, round_params, remat=False)
            h, _ = _dense_block_apply(params["shared_block"], h, cfg,
                                      positions=positions, is_local=flag,
                                      use_pallas=use_pallas, moe_layer=False)
            return h, jnp.zeros((), jnp.float32)

        shared_local = _local_flags(cfg, rounds)
        x, _ = _scan_stack(round_body, x, stacked, flags=shared_local, remat=remat)
        if trailing:
            def tail(h, lp, flag):
                return _mamba_block_apply(lp, h, cfg), jnp.zeros((), jnp.float32)
            x, _ = _scan_stack(tail, x, params["tail_layers"], remat=remat)
    else:
        raise ValueError(fam)

    hidden = L.apply_rmsnorm(params["ln_final"], x, cfg.norm_eps)
    logits = _lm_logits(params, hidden, cfg) if return_logits else None
    return logits, hidden, {"moe_aux": aux_total, "loss_mask": loss_mask}


def _lm_logits(params: Params, hidden, cfg: ModelConfig):
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("btd,dv->btv", hidden, head.astype(hidden.dtype))
    logits = logits.astype(jnp.float32)
    if cfg.final_logit_softcap:
        logits = jnp.tanh(logits / cfg.final_logit_softcap) * cfg.final_logit_softcap
    return logits


# ===========================================================================
# loss
# ===========================================================================

def softmax_xent(logits, labels, mask):
    """Mean next-token cross-entropy over masked positions. logits fp32."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (logz - gold) * mask
    # feddcl-lint: disable=R006  mask is a {0,1} token count: real mass is >= 1 so the 1.0 clamp never deflates, it only turns the all-masked batch into 0/1 = 0 instead of 0/0
    return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)


XENT_CHUNK = 512            # sequence-block size for the chunked CE head


def chunked_xent(params, hidden, labels, mask, cfg: ModelConfig,
                 chunk: int = XENT_CHUNK):
    """CE over the vocab head computed in sequence blocks: the (B, S, V)
    logit tensor (4 GiB/device at 256k vocab × 1M tokens) never materializes
    — peak head temp is (B, chunk, V)."""
    B, S, d = hidden.shape
    if S % chunk or S <= chunk:
        logits = _lm_logits(params, hidden, cfg)
        return softmax_xent(logits, labels, mask)
    n = S // chunk
    hs = hidden.reshape(B, n, chunk, d).swapaxes(0, 1)
    ls = labels.reshape(B, n, chunk).swapaxes(0, 1)
    ms = mask.reshape(B, n, chunk).swapaxes(0, 1)

    def body(carry, xs):
        tot, cnt = carry
        h, l, m = xs
        logits = constrain(_lm_logits(params, h, cfg), "batch", None, "model")
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[..., None], axis=-1)[..., 0]
        tot = tot + jnp.sum((logz - gold) * m)
        cnt = cnt + jnp.sum(m)
        return (tot, cnt), None

    (tot, cnt), _ = L.maybe_scan(body, (jnp.zeros(()), jnp.zeros(())), (hs, ls, ms))
    return tot / jnp.maximum(cnt, 1.0)


def loss_fn(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig, *,
            use_pallas: bool = False, remat: bool = True,
            compute_dtype=jnp.bfloat16, mtp_coef: float = 0.3,
            aux_coef: float = 0.01):
    """batch: tokens (B,S), labels (B,S) (next token, -1 = ignore),
    optional prefix_embeds (B,P,d)."""
    tokens = batch["tokens"]
    labels = batch["labels"]
    _, hidden, aux = forward(
        params, tokens, cfg, prefix_embeds=batch.get("prefix_embeds"),
        use_pallas=use_pallas, remat=remat, compute_dtype=compute_dtype,
        return_logits=False)
    # align: prefix positions carry no labels
    P = hidden.shape[1] - tokens.shape[1]
    tok_hidden = hidden[:, P:]
    mask = (labels >= 0) & aux["loss_mask"][:, P:]
    loss = chunked_xent(params, tok_hidden, jnp.maximum(labels, 0),
                        mask.astype(jnp.float32), cfg)
    metrics = {"ce": loss}
    if cfg.moe is not None:
        loss = loss + aux_coef * aux["moe_aux"]
        metrics["moe_aux"] = aux["moe_aux"]
    if cfg.mtp_depth and "mtp" in params:
        mtp_loss = _mtp_loss(params, hidden[:, P:], tokens, labels, cfg,
                             compute_dtype)
        loss = loss + mtp_coef * mtp_loss
        metrics["mtp"] = mtp_loss
    metrics["loss"] = loss
    return loss, metrics


def _mtp_loss(params, hidden, tokens, labels, cfg: ModelConfig, compute_dtype):
    """DeepSeek-V3 multi-token prediction (depth 1): at position t, combine
    the main hidden state with the embedding of token t+1 and predict t+2."""
    mp = params["mtp"]
    B, S, d = hidden.shape
    h = L.apply_rmsnorm(mp["ln_h"], hidden[:, :-1], cfg.norm_eps)
    e = jnp.take(params["embed"], tokens[:, 1:], axis=0).astype(h.dtype)
    e = L.apply_rmsnorm(mp["ln_e"], e, cfg.norm_eps)
    x = jnp.einsum("bsd,dk->bsk", jnp.concatenate([h, e], -1),
                   mp["proj"].astype(h.dtype))
    positions = jnp.broadcast_to(jnp.arange(S - 1, dtype=jnp.int32), (B, S - 1))
    x, _ = _dense_block_apply(mp["block"], x, cfg, positions=positions,
                              is_local=False, use_pallas=False, moe_layer=False)
    x = L.apply_rmsnorm(params["ln_final"], x, cfg.norm_eps)
    # labels for t+2 = labels shifted left by one; last position invalid
    mtp_labels = labels[:, 1:]                              # (B, S-1)
    mask = (mtp_labels >= 0).astype(jnp.float32)
    # trim to a chunk multiple so the CE head stays chunked at scale
    Sm = x.shape[1]
    keep = (Sm // XENT_CHUNK) * XENT_CHUNK if Sm > XENT_CHUNK else Sm
    return chunked_xent(params, x[:, :keep], jnp.maximum(mtp_labels[:, :keep], 0),
                        mask[:, :keep], cfg)


# ===========================================================================
# prefill: full-sequence forward that also fills the decode cache
# ===========================================================================

def _fill_cache(entries, positions, cache_len: int):
    """entries: (L, B, S, ...) per-position cache writes; keep the last
    min(S, cache_len) positions at ring slots pos % cache_len."""
    Ln, B, S = entries.shape[:3]
    W = min(S, cache_len)
    ent = entries[:, :, S - W:]
    pos = positions[S - W:]                                 # (W,)
    slots = pos % cache_len
    cache = jnp.zeros((Ln, B, cache_len) + entries.shape[3:], entries.dtype)
    cache = cache.at[:, :, slots].set(ent)
    pos_arr = jnp.full((Ln, B, cache_len), -1, jnp.int32)
    pos_arr = pos_arr.at[:, :, slots].set(jnp.broadcast_to(pos, (Ln, B, W)))
    return cache, pos_arr


def prefill(params: Params, tokens, cfg: ModelConfig, *, cache_len: int,
            prefix_embeds=None, use_pallas: bool = False, remat: bool = True,
            compute_dtype=jnp.bfloat16, cache_dtype=jnp.bfloat16):
    """Process a full prompt, returning (last-position logits (B, 1, V),
    decode state matching init_decode_state, next position (B,))."""
    x, positions, _ = embed_inputs(params, tokens, cfg,
                                   prefix_embeds=prefix_embeds)
    x = x.astype(compute_dtype)
    B, T = positions.shape
    pos1d = jnp.arange(T, dtype=jnp.int32)
    fam = cfg.family
    state: Params = {}

    def attn_stack(x, stacked, n, moe_layer):
        flags = _local_flags(cfg, n)

        def body(h, lp, flag):
            hn = L.apply_rmsnorm(lp["ln_attn"], h, cfg.norm_eps)
            if cfg.mla is not None:
                attn, (ckv, krope) = L.mla_attention(
                    lp["attn"], hn, cfg, positions=positions, is_local=flag,
                    return_kv=True)
                entry = (ckv.astype(cache_dtype), krope.astype(cache_dtype))
            else:
                attn, (k, v) = L.multi_head_attention(
                    lp["attn"], hn, cfg, positions=positions, is_local=flag,
                    use_pallas=use_pallas, return_kv=True)
                entry = (k.astype(cache_dtype), v.astype(cache_dtype))
            if cfg.post_block_norm:
                attn = L.apply_rmsnorm(lp["ln_post_attn"], attn, cfg.norm_eps)
            h = h + attn
            hn = L.apply_rmsnorm(lp["ln_mlp"], h, cfg.norm_eps)
            if moe_layer:
                out, _ = L.apply_moe(lp["moe"], hn, cfg)
            else:
                out = L.apply_mlp(lp["mlp"], hn)
            if cfg.post_block_norm:
                out = L.apply_rmsnorm(lp["ln_post_mlp"], out, cfg.norm_eps)
            return h + out, entry

        def f(carry, xs):
            lp, flag = xs
            return body(carry, lp, flag)
        if remat:
            f = jax.checkpoint(f, prevent_cse=L.unroll_mode())
        return L.maybe_scan(f, x, (stacked, flags))

    if fam in ("dense", "audio", "vlm", "moe"):
        if cfg.first_k_dense:
            x, ent = attn_stack(x, params["dense_layers"], cfg.first_k_dense, False)
            state["dense_cache"] = _entries_to_cache(ent, pos1d, cache_len, cfg)
        n_main = (cfg.num_layers - cfg.first_k_dense) if fam == "moe" else cfg.num_layers
        x, ent = attn_stack(x, params["layers"], n_main, fam == "moe")
        state["cache"] = _entries_to_cache(ent, pos1d, cache_len, cfg)

    elif fam == "ssm":
        def body(h, lp, flag):
            hn = L.apply_rmsnorm(lp["ln_att"], h, cfg.norm_eps)
            att, wkv = L.rwkv6_timemix(lp["tm"], hn, cfg, return_state=True)
            h = h + att
            hf = L.apply_rmsnorm(lp["ln_ffn"], h, cfg.norm_eps)
            hf_prev = jnp.pad(hf, ((0, 0), (1, 0), (0, 0)))[:, :-1]
            h = h + L.rwkv6_channelmix(lp["cm"], hf, hf_prev)
            return h, (wkv, hn[:, -1].astype(jnp.float32), hf[:, -1].astype(jnp.float32))

        def f(carry, xs):
            lp, flag = xs
            return body(carry, lp, flag)
        if remat:
            f = jax.checkpoint(f, prevent_cse=L.unroll_mode())
        x, (wkv, xpa, xpf) = L.maybe_scan(
            f, x, (params["layers"], jnp.zeros((cfg.num_layers,), bool)))
        state.update({"wkv": wkv, "x_prev_att": xpa, "x_prev_ffn": xpf})

    elif fam == "hybrid":
        rounds, trailing = _hybrid_split(cfg)
        per = cfg.hybrid_period
        stacked = jax.tree.map(
            lambda a: a.reshape((rounds, per) + a.shape[1:]), params["layers"])
        is_local = jnp.asarray(cfg.attn_variant == "sliding")

        def round_body(h, rp, flag):
            def inner(hh, lp, _):
                hn = L.apply_rmsnorm(lp["ln"], hh, cfg.norm_eps)
                out, st = L.mamba2_forward(lp["mamba"], hn, cfg, return_state=True)
                return hh + out, st
            h, mstates = L.maybe_scan(lambda c, xs: inner(c, xs, None), h, rp)
            hn = L.apply_rmsnorm(params["shared_block"]["ln_attn"], h, cfg.norm_eps)
            attn, (k, v) = L.multi_head_attention(
                params["shared_block"]["attn"], hn, cfg, positions=positions,
                is_local=is_local, use_pallas=use_pallas, return_kv=True)
            h = h + attn
            hn = L.apply_rmsnorm(params["shared_block"]["ln_mlp"], h, cfg.norm_eps)
            h = h + L.apply_mlp(params["shared_block"]["mlp"], hn)
            return h, (mstates, k.astype(cache_dtype), v.astype(cache_dtype))

        def f(carry, xs):
            rp, flag = xs
            return round_body(carry, rp, flag)
        if remat:
            f = jax.checkpoint(f, prevent_cse=L.unroll_mode())
        x, (mstates, ks, vs) = L.maybe_scan(
            f, x, (stacked, jnp.zeros((rounds,), bool)))
        conv = mstates[0].reshape((rounds * per,) + mstates[0].shape[2:])
        ssm = mstates[1].reshape((rounds * per,) + mstates[1].shape[2:])
        state["mamba"] = {"conv": conv, "ssm": ssm}
        kc, pos_arr = _fill_cache(ks, pos1d, cache_len)
        vc, _ = _fill_cache(vs, pos1d, cache_len)
        state["shared_cache"] = {"k": kc, "v": vc, "pos": pos_arr}
        if trailing:
            def tail(hh, xs):
                lp = xs
                hn = L.apply_rmsnorm(lp["ln"], hh, cfg.norm_eps)
                out, st = L.mamba2_forward(lp["mamba"], hn, cfg, return_state=True)
                return hh + out, st
            x, tstates = L.maybe_scan(tail, x, params["tail_layers"])
            state["mamba_tail"] = {"conv": tstates[0], "ssm": tstates[1]}

    hidden = L.apply_rmsnorm(params["ln_final"], x[:, -1:], cfg.norm_eps)
    logits = _lm_logits(params, hidden, cfg)
    next_pos = jnp.full((B,), T, jnp.int32)
    return logits, state, next_pos


def _entries_to_cache(ent, pos1d, cache_len: int, cfg: ModelConfig):
    if cfg.mla is not None:
        ckv, krope = ent
        c1, pos_arr = _fill_cache(ckv, pos1d, cache_len)
        c2, _ = _fill_cache(krope, pos1d, cache_len)
        return {"ckv": c1, "krope": c2, "pos": pos_arr}
    k, v = ent
    kc, pos_arr = _fill_cache(k, pos1d, cache_len)
    vc, _ = _fill_cache(v, pos1d, cache_len)
    return {"k": kc, "v": vc, "pos": pos_arr}


# ===========================================================================
# decode (single token, cached)
# ===========================================================================

def init_decode_state(cfg: ModelConfig, batch: int, cache_len: int,
                      dtype=jnp.bfloat16) -> Params:
    """Cache pytree for serve_step. cache_len should be min(seq_len, window)
    for pure sliding-window configs."""
    fam = cfg.family
    state: Params = {}
    if fam in ("dense", "audio", "vlm", "moe"):
        n_main = cfg.num_layers - cfg.first_k_dense if fam == "moe" else cfg.num_layers
        if cfg.mla is not None:
            if cfg.first_k_dense:
                state["dense_cache"] = L.init_mla_cache(cfg, batch, cache_len,
                                                        cfg.first_k_dense, dtype)
            state["cache"] = L.init_mla_cache(cfg, batch, cache_len, n_main, dtype)
        else:
            if cfg.first_k_dense:
                state["dense_cache"] = L.init_kv_cache(cfg, batch, cache_len,
                                                       cfg.first_k_dense, dtype)
            state["cache"] = L.init_kv_cache(cfg, batch, cache_len, n_main, dtype)
    elif fam == "ssm":
        H, hd = cfg.num_heads, cfg.ssm.head_dim
        state["wkv"] = jnp.zeros((cfg.num_layers, batch, H, hd, hd), jnp.float32)
        state["x_prev_att"] = jnp.zeros((cfg.num_layers, batch, cfg.d_model), jnp.float32)
        state["x_prev_ffn"] = jnp.zeros((cfg.num_layers, batch, cfg.d_model), jnp.float32)
    elif fam == "hybrid":
        rounds, trailing = _hybrid_split(cfg)
        state["mamba"] = L.init_mamba2_cache(cfg, batch, rounds * cfg.hybrid_period)
        if trailing:
            state["mamba_tail"] = L.init_mamba2_cache(cfg, batch, trailing)
        state["shared_cache"] = L.init_kv_cache(cfg, batch, cache_len, rounds, dtype)
    return state


def decode_step(params: Params, state: Params, tokens, cur_pos,
                cfg: ModelConfig, *, compute_dtype=jnp.bfloat16):
    """One decode step. tokens: (B, 1) int32; cur_pos: (B,) absolute position.
    Returns (logits (B, 1, V) fp32, new_state)."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens[:, 0], axis=0)[:, None]
    if cfg.scale_embeddings:
        x = x * math.sqrt(cfg.d_model)
    x = x.astype(compute_dtype)
    fam = cfg.family
    new_state = dict(state)

    if fam in ("dense", "audio", "vlm", "moe"):
        if cfg.first_k_dense:
            x, new_state["dense_cache"] = _decode_attn_stack(
                params["dense_layers"], state["dense_cache"], x, cur_pos, cfg,
                moe_layer=False, n=cfg.first_k_dense)
        n_main = (cfg.num_layers - cfg.first_k_dense) if fam == "moe" else cfg.num_layers
        x, new_state["cache"] = _decode_attn_stack(
            params["layers"], state["cache"], x, cur_pos, cfg,
            moe_layer=(fam == "moe"), n=n_main)

    elif fam == "ssm":
        def body(carry, xs):
            h = carry
            lp, wkv, xpa, xpf = xs
            out, new_wkv, new_xpa, new_xpf = L.rwkv6_decode_step(
                lp["tm"], lp["cm"], h, cfg, state=wkv, x_prev_att=xpa,
                x_prev_ffn=xpf, norm_att=lp["ln_att"], norm_ffn=lp["ln_ffn"])
            return out, (new_wkv, new_xpa, new_xpf)
        x, (wkv, xpa, xpf) = L.maybe_scan(
            body, x, (params["layers"], state["wkv"], state["x_prev_att"],
                      state["x_prev_ffn"]))
        new_state.update({"wkv": wkv, "x_prev_att": xpa, "x_prev_ffn": xpf})

    elif fam == "hybrid":
        rounds, trailing = _hybrid_split(cfg)
        per = cfg.hybrid_period
        reshape = lambda a: a.reshape((rounds, per) + a.shape[1:])
        stacked = jax.tree.map(reshape, params["layers"])
        mcache = {k: reshape(v) for k, v in state["mamba"].items()}
        is_local = cfg.attn_variant == "sliding"

        def round_body(carry, xs):
            h = carry
            rp, conv, ssm, ck, cv, cp = xs

            def inner(hh, ys):
                lp, cv_, ss_ = ys
                hn = L.apply_rmsnorm(lp["ln"], hh, cfg.norm_eps)
                out, nc, ns = L.mamba2_decode_step(lp["mamba"], hn, cfg,
                                                   conv_state=cv_, ssm_state=ss_)
                return hh + out, (nc, ns)
            h, (nconv, nssm) = L.maybe_scan(inner, h, (rp, conv, ssm))
            hn = L.apply_rmsnorm(params["shared_block"]["ln_attn"], h, cfg.norm_eps)
            attn, (nk, nv, npos) = L.decode_attention(
                params["shared_block"]["attn"], hn, cfg, cache_k=ck, cache_v=cv,
                cache_pos=cp, cur_pos=cur_pos, is_local=is_local)
            h = h + attn
            hn = L.apply_rmsnorm(params["shared_block"]["ln_mlp"], h, cfg.norm_eps)
            h = h + L.apply_mlp(params["shared_block"]["mlp"], hn)
            return h, (nconv, nssm, nk, nv, npos)

        x, (nconv, nssm, nk, nv, npos) = L.maybe_scan(
            round_body, x,
            (stacked, mcache["conv"], mcache["ssm"],
             state["shared_cache"]["k"], state["shared_cache"]["v"],
             state["shared_cache"]["pos"]))
        unshape = lambda a: a.reshape((rounds * per,) + a.shape[2:])
        new_state["mamba"] = {"conv": unshape(nconv), "ssm": unshape(nssm)}
        new_state["shared_cache"] = {"k": nk, "v": nv, "pos": npos}
        if trailing:
            def tail(carry, xs):
                h = carry
                lp, conv, ssm = xs
                hn = L.apply_rmsnorm(lp["ln"], h, cfg.norm_eps)
                out, nc, ns = L.mamba2_decode_step(lp["mamba"], hn, cfg,
                                                   conv_state=conv, ssm_state=ssm)
                return h + out, (nc, ns)
            x, (tc, ts) = L.maybe_scan(tail, x, (params["tail_layers"],
                                             state["mamba_tail"]["conv"],
                                             state["mamba_tail"]["ssm"]))
            new_state["mamba_tail"] = {"conv": tc, "ssm": ts}

    hidden = L.apply_rmsnorm(params["ln_final"], x, cfg.norm_eps)
    logits = _lm_logits(params, hidden, cfg)
    return logits, new_state


def _decode_attn_stack(stacked, cache, x, cur_pos, cfg: ModelConfig, *,
                       moe_layer: bool, n: int):
    flags = _local_flags(cfg, n)
    use_mla = cfg.mla is not None

    def body(carry, xs):
        h = carry
        if use_mla:
            lp, ckv, krope, cpos, flag = xs
        else:
            lp, ck, cv, cpos, flag = xs
        hn = L.apply_rmsnorm(lp["ln_attn"], h, cfg.norm_eps)
        if use_mla:
            attn, (nckv, nkrope, npos) = L.mla_decode(
                lp["attn"], hn, cfg, cache_ckv=ckv, cache_krope=krope,
                cache_pos=cpos, cur_pos=cur_pos, is_local=flag)
        else:
            attn, (nk, nv, npos) = L.decode_attention(
                lp["attn"], hn, cfg, cache_k=ck, cache_v=cv, cache_pos=cpos,
                cur_pos=cur_pos, is_local=flag)
        if cfg.post_block_norm:
            attn = L.apply_rmsnorm(lp["ln_post_attn"], attn, cfg.norm_eps)
        h = h + attn
        hn = L.apply_rmsnorm(lp["ln_mlp"], h, cfg.norm_eps)
        if moe_layer:
            out, _ = L.apply_moe(lp["moe"], hn, cfg)
        else:
            out = L.apply_mlp(lp["mlp"], hn)
        if cfg.post_block_norm:
            out = L.apply_rmsnorm(lp["ln_post_mlp"], out, cfg.norm_eps)
        if use_mla:
            return h + out, (nckv, nkrope, npos)
        return h + out, (nk, nv, npos)

    if use_mla:
        xs = (stacked, cache["ckv"], cache["krope"], cache["pos"], flags)
        x, (a, b, c) = L.maybe_scan(body, x, xs)
        return x, {"ckv": a, "krope": b, "pos": c}
    xs = (stacked, cache["k"], cache["v"], cache["pos"], flags)
    x, (a, b, c) = L.maybe_scan(body, x, xs)
    return x, {"k": a, "v": b, "pos": c}


# ===========================================================================
# analytic parameter counts (exact — from eval_shape of init)
# ===========================================================================

@functools.lru_cache(maxsize=None)
def _param_shapes(cfg: ModelConfig):
    shapes = jax.eval_shape(
        lambda: init_params(cfg, jax.random.PRNGKey(0), jnp.float32))
    return shapes


def count_params_analytic(cfg: ModelConfig, active_only: bool = False,
                          include_embed: bool = True) -> int:
    shapes = _param_shapes(cfg)
    total = sum(int(np.prod(s.shape)) for s in jax.tree_util.tree_leaves(shapes))
    if not include_embed:
        total -= cfg.vocab_size * cfg.d_model
        if not cfg.tie_embeddings:
            total -= cfg.vocab_size * cfg.d_model
    if active_only and cfg.moe is not None:
        mo = cfg.moe
        n_moe = cfg.num_layers - cfg.first_k_dense
        inactive = n_moe * 3 * cfg.d_model * mo.d_ff_expert * (mo.num_experts - mo.top_k)
        total -= inactive
    return total
