"""Expert-parallel MoE via shard_map + all_to_all (the hillclimbed path).

The baseline GSPMD dispatch (layers.apply_moe_gspmd) scatters token rows
into an expert-major buffer and lets the partitioner reshard — which it does
by replicating the (T·k, d) operand (measured: granite train_4k temp 92 GiB
/dev, 2.2 TB/dev collectives). This path makes the exchange explicit:

  tokens stay sharded over the batch axes; experts are sharded over "model";
  each device routes its local tokens, packs per-expert capacity buffers,
  and ONE tiled all_to_all over the model axis moves exactly
  E·cap_local·d bytes to the expert owners (and one back).

Falls back to the GSPMD path when no multi-device mesh is active (CPU tests)
or when tracing under vmap (federated silo dim — shard_map does not nest
under vmap; the fed plans pin impl="gspmd").
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig


def _physical_mesh():
    try:
        from jax._src import mesh as mesh_lib
        pm = mesh_lib.thread_resources.env.physical_mesh
        if not pm.empty:
            return pm
    except Exception:  # pragma: no cover
        pass
    return None


def _shard_map(f, mesh, in_specs, out_specs):
    try:
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    except (AttributeError, TypeError):
        from jax.experimental.shard_map import shard_map as sm
        return sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)


def apply_moe_ep(p, x: jnp.ndarray, cfg: ModelConfig) -> Tuple[jnp.ndarray, jnp.ndarray]:
    from repro.models.layers import _router_probs, apply_mlp, moe_aux_loss

    mesh = _physical_mesh()
    mo = cfg.moe
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape)) if mesh else {}
    M = sizes.get("model", 1)
    if mesh is None or M <= 1 or mo.num_experts % M:
        from repro.models.layers import apply_moe_gspmd
        return apply_moe_gspmd(p, x, cfg)

    batch_axes = tuple(a for a in ("pod", "data") if sizes.get(a, 1) > 1)
    B, S, d = x.shape
    E, k = mo.num_experts, mo.top_k
    E_loc = E // M

    has_bias = "router_bias" in p

    data_axis = "data" if sizes.get("data", 1) > 1 else None

    def local_fn(xl, router, router_bias, wg, wu, wd):
        # xl: (B_loc, S_loc, d) — this device's token block.
        # Expert weights arrive FSDP-sharded on their wide dim (P('model',
        # ·,'data')) — deepseek's experts are 96% of its 671B params, so
        # keeping them data-sharded at rest is mandatory (measured: 647
        # GiB/dev without). Gather per layer, exactly like FSDP elsewhere.
        if data_axis is not None:
            wg = lax.all_gather(wg, data_axis, axis=2, tiled=True)
            wu = lax.all_gather(wu, data_axis, axis=2, tiled=True)
            wd = lax.all_gather(wd, data_axis, axis=1, tiled=True)
        Bl, Sl = xl.shape[0], xl.shape[1]
        T_loc = Bl * Sl
        x2d = xl.reshape(T_loc, d)
        pr = {"router": router}
        if has_bias:
            pr["router_bias"] = router_bias
        gates, idx, probs = _router_probs(pr, x2d, mo)
        cap = max(int(mo.capacity_factor * T_loc * k / E), 1)

        flat_e = idx.reshape(-1)
        order = jnp.argsort(flat_e, stable=True)
        sorted_e = flat_e[order]
        starts = jnp.searchsorted(sorted_e, jnp.arange(E))
        pos_sorted = jnp.arange(T_loc * k) - starts[sorted_e]
        pos = jnp.zeros((T_loc * k,), jnp.int32).at[order].set(
            pos_sorted.astype(jnp.int32))
        keep = pos < cap
        slot = jnp.where(keep, pos, cap)

        src = jnp.repeat(x2d, k, axis=0)
        buf = jnp.zeros((E, cap + 1, d), x.dtype).at[flat_e, slot].set(src)
        buf = buf[:, :cap]                                   # (E, cap, d)

        # ONE exchange: (E, cap, d) -> (E_loc, M*cap, d)
        recv = lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                              tiled=True)

        g = jnp.einsum("ecd,edf->ecf", recv, wg.astype(x.dtype))
        u = jnp.einsum("ecd,edf->ecf", recv, wu.astype(x.dtype))
        y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, wd.astype(x.dtype))

        # reverse exchange back to token owners: (E_loc, M*cap, d) -> (E, cap, d)
        back = lax.all_to_all(y, "model", split_axis=1, concat_axis=0,
                              tiled=True)
        back = jnp.concatenate([back, jnp.zeros((E, 1, d), y.dtype)], axis=1)
        got = back[flat_e, slot]                             # (T_loc*k, d)
        w = (gates.reshape(-1) * keep.astype(jnp.float32)).astype(x.dtype)
        out = jnp.sum((got * w[:, None]).reshape(T_loc, k, d), axis=1)
        aux = moe_aux_loss(probs, idx, mo)
        return out.reshape(Bl, Sl, d), aux[None]

    # tokens are sharded over batch AND (sequence-wise) over model: without
    # the model split every model-peer in a data row would route the SAME
    # replicated tokens — 16× duplicated dispatch+expert work (measured:
    # granite compute 496→1234 ms before this fix).
    if S % M:
        from repro.models.layers import apply_moe_gspmd
        return apply_moe_gspmd(p, x, cfg)
    x_spec = P(batch_axes if batch_axes else None, "model", None)
    d_ax = "data" if sizes.get("data", 1) > 1 else None
    gate_spec = P("model", None, d_ax)     # (E, d, f): FSDP on f
    down_spec = P("model", d_ax, None)     # (E, f, d): FSDP on f
    rb = p.get("router_bias")
    aux_axes = tuple(batch_axes) + ("model",)
    fn = _shard_map(
        local_fn, mesh,
        in_specs=(x_spec, P(), P(), gate_spec, gate_spec, down_spec),
        out_specs=(x_spec, P(aux_axes)),
    )
    out, aux = fn(x, p["router"], rb if rb is not None else jnp.zeros((0,)),
                  p["w_gate"], p["w_up"], p["w_down"])
    aux = jnp.mean(aux)
    if mo.num_shared_experts:
        out = out + apply_mlp(p["shared"], x.reshape(-1, d)).reshape(B, S, d)
    return out, aux
