"""Fully-connected nets for the paper's tabular experiments (§4).

Matches the paper's setup: layers [{m, m̂} - hidden… - out], sigmoid-free
ReLU hidden activations, linear output for regression / logits for
classification. Trained with the substrate optimizer (optim/) under
Centralized / Local / FedAvg / DC / FedDCL drivers (core/).
"""
from __future__ import annotations

from typing import Any, Dict, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.feddcl_mlp import MLPConfig

Params = Dict[str, Any]


def init_mlp_params(key, in_dim: int, hidden: Sequence[int], out_dim: int,
                    dtype=jnp.float32) -> Params:
    dims = [in_dim, *hidden, out_dim]
    keys = jax.random.split(key, len(dims) - 1)
    layers = []
    for i, k in enumerate(keys):
        w = jax.random.normal(k, (dims[i], dims[i + 1]), jnp.float32)
        w = w * jnp.sqrt(2.0 / dims[i])
        layers.append({"w": w.astype(dtype), "b": jnp.zeros((dims[i + 1],), dtype)})
    return {"layers": layers}


def mlp_forward(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = x
    n = len(params["layers"])
    for i, lp in enumerate(params["layers"]):
        h = h @ lp["w"] + lp["b"]
        if i < n - 1:
            h = jax.nn.relu(h)
    return h


def mlp_per_example_loss(params: Params, x: jnp.ndarray, y: jnp.ndarray,
                         task: str) -> jnp.ndarray:
    """(n,) per-example losses — what the federated engine masks/weights for
    zero-padded ragged silos (core/federated.py). mlp_loss is its mean."""
    pred = mlp_forward(params, x)
    if task == "regression":
        return jnp.mean(jnp.square(pred - y), axis=-1)
    logz = jax.nn.logsumexp(pred, axis=-1)
    gold = jnp.take_along_axis(pred, y.astype(jnp.int32)[:, None], axis=-1)[:, 0]
    return logz - gold


def mlp_loss(params: Params, x: jnp.ndarray, y: jnp.ndarray, task: str,
             l2: float = 0.0) -> jnp.ndarray:
    loss = jnp.mean(mlp_per_example_loss(params, x, y, task))
    if l2:
        sq = sum(jnp.sum(jnp.square(lp["w"])) for lp in params["layers"])
        loss = loss + l2 * sq
    return loss


def mlp_metric(params: Params, x: jnp.ndarray, y: jnp.ndarray, task: str) -> float:
    """RMSE for regression (paper Fig. 4/5), accuracy for classification."""
    pred = mlp_forward(params, x)
    if task == "regression":
        return float(jnp.sqrt(jnp.mean(jnp.square(pred - y))))
    return float(jnp.mean(jnp.argmax(pred, -1) == y.astype(jnp.int32)))


def for_config(key, cfg: MLPConfig, *, reduced: bool, dtype=jnp.float32) -> Params:
    in_dim = cfg.reduced_dim if reduced else cfg.in_dim
    return init_mlp_params(key, in_dim, cfg.hidden, cfg.out_dim, dtype)
