"""Activation sharding constraints that are no-ops outside a mesh context.

Model code calls constrain(x, "batch", None, "model", ...) with LOGICAL axis
names; under `with mesh:` they resolve to the mesh's physical axes ("batch"
-> ("pod", "data") as available, "model" -> "model") and emit
with_sharding_constraint; on a single host device (smoke tests, benchmarks)
they vanish. Dims whose size is not divisible by the resolved axes are
silently left unsharded — the same fallback philosophy as shardingx.policy.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import PartitionSpec as P

Axis = Union[None, str, Tuple[str, ...]]


def _mesh_axes() -> dict:
    # `with mesh:` sets the legacy thread-resources context (what
    # with_sharding_constraint's spec-only form consumes).
    try:
        from jax._src import mesh as mesh_lib
        pm = mesh_lib.thread_resources.env.physical_mesh
        if not pm.empty:
            return dict(zip(pm.axis_names, pm.devices.shape))
    except Exception:       # pragma: no cover
        pass
    try:
        m = jax.sharding.get_abstract_mesh()
        if m is not None and getattr(m, "axis_names", None):
            return dict(zip(m.axis_names, m.axis_sizes))
    except Exception:       # pragma: no cover
        pass
    return {}


import contextlib

# Federated tracing context: the silo mesh axis carries the vmapped silo
# dim, so logical "batch" must NOT resolve onto it (otherwise GSPMD moves
# per-silo activations across the silo boundary — measured as spurious
# cross-pod traffic in the fed local step).
_SILO_AXIS: list = [None]


@contextlib.contextmanager
def silo_context(axis: str):
    _SILO_AXIS.append(axis)
    try:
        yield
    finally:
        _SILO_AXIS.pop()


def resolve_axis(logical: Axis, sizes: dict) -> Tuple[str, ...]:
    if logical is None:
        return ()
    excluded = _SILO_AXIS[-1]
    if logical == "batch":
        return tuple(a for a in ("pod", "data")
                     if sizes.get(a, 1) > 1 and a != excluded)
    if isinstance(logical, str):
        return (logical,) if sizes.get(logical, 1) > 1 and logical != excluded else ()
    return tuple(a for a in logical if sizes.get(a, 1) > 1 and a != excluded)


def constrain(x, *logical: Axis):
    """x with a sharding constraint following the logical spec; identity when
    no mesh is active or the spec fully degenerates."""
    sizes = _mesh_axes()
    if not sizes:
        return x
    assert len(logical) == x.ndim, (logical, x.shape)
    entries = []
    any_sharded = False
    for dim, name in zip(x.shape, logical):
        axes = resolve_axis(name, sizes)
        prod = 1
        for a in axes:
            prod *= sizes[a]
        if axes and dim % prod == 0 and dim >= prod:
            entries.append(axes if len(axes) > 1 else axes[0])
            any_sharded = True
        else:
            entries.append(None)
    if not any_sharded:
        return x
    return jax.lax.with_sharding_constraint(x, P(*entries))
