"""Per-tensor PartitionSpec resolution for the production meshes.

Handles the awkward real-world cases the assigned architectures hit:
  * GQA kv_heads (8, 4, 2) smaller than the 16-wide model axis — falls back
    to head_dim sharding, then to replication;
  * RWKV6's 40 heads (not divisible by 16) — shards head_dim instead;
  * granite's vocab 49155 = 3·5·29·113 — not divisible by ANY mesh axis, so
    the embedding shards d_model on the model axis instead;
  * stacked scan-over-layers parameters (leading layer dim) — rules are
    written against trailing (negative) dims;
  * federated training — a leading silo dim sharded over the silo axis,
    with FSDP restricted to the intra-silo data axis.

Design choices (DESIGN.md §5): tensor parallelism over "model", FSDP over
"data" only (cross-pod gathers would ride the scarce DCI), "pod" is pure
data parallel in baseline mode and the silo axis in federated mode.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

REPLICATE_BELOW = 4096          # leaves smaller than this stay replicated

# name -> (model-axis dim priority, data/FSDP-axis dim priority), negative
# indices relative to the trailing (per-layer) shape.
_RULES_3D = {
    # attention / rwkv projections (d, H, hd) — prefer heads, fall to head_dim
    "wq": ([-2, -1], [-3]), "wk": ([-2, -1], [-3]), "wv": ([-2, -1], [-3]),
    "w_r": ([-2, -1], [-3]), "w_k": ([-2, -1], [-3]), "w_v": ([-2, -1], [-3]),
    # output projections (H, hd, d)
    "wo": ([-3, -2], [-1]), "w_o": ([-3, -2], [-1]),
    # MoE experts (E, d, f) / (E, f, d) — expert parallelism on model axis
    "w_gate": ([-3], [-1]), "w_up": ([-3], [-1]), "w_down": ([-3], [-2]),
    # MLA up-projections (rank, H, x)
    "w_uq": ([-2], [-3]), "w_uk": ([-2], [-3]), "w_uv": ([-2], [-3]),
    # rwkv lora tails
    "decay_lora_b": ([-1], [-3]), "mix_lora_a": ([-1], [-3]),
    "mix_lora_b": ([-1], [-2]),
}

_RULES_2D_UP = {"w_gate", "w_up", "w_k", "w_g", "w_in", "w_dq", "w_dkv",
                "decay_lora_a", "proj", "w_r"}
_RULES_2D_DOWN = {"w_down", "w_v", "w_out", "lm_head"}
_EMBED = {"embed"}


def _divisible(shape: Sequence[int], dim: int, size: int) -> bool:
    return size > 1 and shape[dim] % size == 0


def _resolve(name: str, shape: Tuple[int, ...], trailing: int,
             model_axis: Optional[str], model_size: int,
             data_axis: Optional[str], data_size: int,
             fsdp: bool) -> list:
    """Return spec entries for the trailing `trailing` dims."""
    spec: list = [None] * trailing
    if int(np.prod(shape[-trailing:] or (1,))) < REPLICATE_BELOW or trailing == 0:
        return spec

    def t2a(neg: int) -> int:  # negative trailing index -> index into spec
        return trailing + neg

    model_dims, data_dims = [], []
    if trailing >= 3 and name in _RULES_3D:
        model_dims, data_dims = _RULES_3D[name]
    elif trailing >= 2 and name in _EMBED:
        model_dims, data_dims = [-2, -1], [-2, -1]
    elif trailing >= 2 and name in _RULES_2D_DOWN:
        model_dims, data_dims = [-2], [-1]
    elif trailing >= 2 and (name in _RULES_2D_UP or trailing == 2):
        model_dims, data_dims = [-1], [-2]

    model_at = None
    if model_axis:
        for nd in model_dims:
            if -nd <= trailing and _divisible(shape, nd, model_size):
                spec[t2a(nd)] = model_axis
                model_at = t2a(nd)
                break
    if fsdp and data_axis:
        for nd in data_dims:
            a = t2a(nd)
            if -nd <= trailing and a != model_at and _divisible(shape, nd, data_size):
                spec[a] = data_axis
                break
        else:
            # try stacking data onto the model dim (e.g. embed vocab over both)
            if model_at is not None and shape[model_at - trailing] % (model_size * data_size) == 0:
                spec[model_at] = (data_axis, model_axis)
    return spec


_MOE_EXPERT_NAMES = {"w_gate", "w_up", "w_down"}


def param_specs(shapes: Any, mesh: Mesh, *, fsdp: bool = True,
                moe_fsdp: bool = True,
                silo_dim: bool = False, silo_axis: Optional[str] = None,
                stacked_prefixes: Tuple[str, ...] = ("layers", "dense_layers",
                                                     "tail_layers")) -> Any:
    """Tree of PartitionSpec matching a tree of ShapeDtypeStructs/arrays.

    silo_dim: params carry a leading silo dim (federated mode) sharded over
    silo_axis; FSDP then uses the remaining data axis only if distinct.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    model_axis = "model" if "model" in axis_sizes else None
    if silo_dim and silo_axis is None:
        silo_axis = "pod" if "pod" in axis_sizes else "data"
    data_axis = "data" if "data" in axis_sizes else None
    if silo_dim and silo_axis == "data":
        data_axis = None                      # data axis consumed by silos

    def one(path, leaf) -> P:
        shape = tuple(leaf.shape)
        names = [p.key for p in path if isinstance(p, jax.tree_util.DictKey)]
        name = names[-1] if names else ""
        if name in ("scale",) and len(names) >= 2:
            name = names[-2]                  # rmsnorm dicts
        offset = 1 if silo_dim else 0
        stacked = any(n in stacked_prefixes for n in names)
        trailing = len(shape) - offset - (1 if stacked and len(shape) - offset >= 1 else 0)
        trailing = max(trailing, 0)
        leaf_fsdp = fsdp
        # expert-parallel MoE (shard_map path) needs expert weights sharded
        # exactly P(model-on-E) — no FSDP on the d/f dims
        if not moe_fsdp and name in _MOE_EXPERT_NAMES and trailing >= 3:
            leaf_fsdp = False
        entries = _resolve(name, shape, trailing, model_axis,
                           axis_sizes.get(model_axis or "", 1),
                           data_axis, axis_sizes.get(data_axis or "", 1),
                           leaf_fsdp)
        head: list = []
        if silo_dim:
            head.append(silo_axis if shape[0] > 1 else None)
        if stacked and len(shape) - offset >= 1:
            head.append(None)                 # layer-stack dim
        return P(*(head + entries))

    return jax.tree_util.tree_map_with_path(one, shapes)


def batch_spec(mesh: Mesh, *, federated: bool,
               silo_axis: Optional[Any] = None, ndim: int = 2) -> P:
    """Spec for (B, S) token batches — or federated silo stacks.

    federated with a STRING silo_axis (launch-tier LLM batches, (d, b, S)):
    silo dim over silo_axis, intra-silo batch dim over the leftover "data"
    axis, ndim counting the per-silo batch rank. federated with a TUPLE
    silo_axis (core.federated sharded plans, (d, n_slots, …) tabular
    stacks): the leading silo dim spans ALL the named axes jointly —
    ("pod", "data") on a multipod mesh — and ndim is the FULL array rank;
    every non-silo dim stays shard-local.
    """
    axis_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if federated:
        if silo_axis is not None and not isinstance(silo_axis, str):
            return P(tuple(silo_axis), *([None] * (ndim - 1)))
        silo_axis = silo_axis or ("pod" if "pod" in axis_sizes else "data")
        rest = "data" if ("data" in axis_sizes and silo_axis != "data") else None
        return P(silo_axis, rest, *([None] * (ndim - 1)))
    batch_axes = tuple(a for a in ("pod", "data") if a in axis_sizes)
    return P(batch_axes if batch_axes else None, *([None] * (ndim - 1)))


def to_shardings(spec_tree: Any, mesh: Mesh) -> Any:
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda x: isinstance(x, P))
