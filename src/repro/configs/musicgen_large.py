"""musicgen-large — decoder-only over EnCodec audio tokens. [arXiv:2306.05284]

The EnCodec/conditioning frontend is a STUB per the brief: input_specs()
supplies precomputed conditioning frame embeddings (batch, prefix_len, d_model)
that the decoder consumes via prefix fusion; the token stream is the EnCodec
codebook stream (vocab 2048).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=2048,
    prefix_frontend=True,
    prefix_len=64,
    source="arXiv:2306.05284",
)
