"""Paper-faithful tabular MLP configs — the networks of Table 3.

The paper trains fully-connected nets on (collaboration representations of)
six tabular datasets. Layer widths [{m, m_hat} - hidden... - out] per Table 3.
"""
from dataclasses import dataclass
from typing import Tuple


@dataclass(frozen=True)
class MLPConfig:
    name: str
    in_dim: int                 # m (raw) — replaced by m_hat for DC/FedDCL
    hidden: Tuple[int, ...]
    out_dim: int
    task: str                   # "regression" | "classification"
    reduced_dim: int            # m_hat = m_tilde (Table 3)


# Table 3 of the paper (network layers [{m, m̂}-…]).
PAPER_MLPS = {
    "battery_small": MLPConfig("battery_small", 5, (20,), 1, "regression", 4),
    "credit_rating": MLPConfig("credit_rating", 17, (50,), 1, "regression", 15),
    "eicu": MLPConfig("eicu", 24, (10,), 1, "regression", 15),
    "human_activity": MLPConfig("human_activity", 60, (80,), 5, "classification", 50),
    "mnist": MLPConfig("mnist", 784, (500, 100), 10, "classification", 50),
    "fashion_mnist": MLPConfig("fashion_mnist", 784, (500, 100), 10, "classification", 50),
}
