"""deepseek-v3-671b — MLA + 1 shared / 256 routed top-8 MoE + MTP. [arXiv:2412.19437]"""
from repro.configs.base import MLAConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="deepseek-v3-671b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=128,               # MLA: latent-shared KV; head count for q
    head_dim=128,                   # v head dim
    d_ff=18432,                     # dense FFN width for the first_k_dense layers
    vocab_size=129280,
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff_expert=2048,
        num_shared_experts=1,
        d_ff_shared=2048,
        router="sigmoid",
        routed_scaling=2.5,
    ),
    mla=MLAConfig(
        q_lora_rank=1536,
        kv_lora_rank=512,
        qk_nope_head_dim=128,
        qk_rope_head_dim=64,
        v_head_dim=128,
    ),
    first_k_dense=3,
    mtp_depth=1,
    source="arXiv:2412.19437",
)
