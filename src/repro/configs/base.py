"""Config dataclasses for architectures, input shapes, and runs.

Every assigned architecture (see configs/<arch>.py) instantiates ModelConfig.
Configs are plain frozen dataclasses so they hash/compare and can key jit
caches. No jax imports here — configs must be importable without touching
device state (the dry-run sets XLA_FLAGS before any jax import).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts block config (Switch/DeepSeek style)."""

    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    router: str = "softmax"           # softmax | sigmoid (deepseek-v3)
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01     # load-balance loss coefficient
    routed_scaling: float = 1.0       # deepseek-v3 routed expert scaling
    # Expert-parallel implementation: "dense" (tiny smoke configs only),
    # "gspmd" (scatter-based dispatch, auto-partitioned), or
    # "ep" (shard_map all_to_all expert parallelism over the model axis).
    impl: str = "gspmd"


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""

    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_head_dim: int
    qk_rope_head_dim: int
    v_head_dim: int


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / RWKV6 recurrent block config."""

    kind: str                 # "mamba2" | "rwkv6"
    state_dim: int = 64       # N (mamba2 state size) — per-head value dim for rwkv6
    head_dim: int = 64
    expand: int = 2           # mamba2 inner expansion
    conv_dim: int = 4         # mamba2 depthwise conv width
    dt_rank: int = 0          # unused by mamba2 (uses per-head dt)
    chunk: int = 128          # chunked-scan block length
    # recurrent-chunk sharding over the model axis: "k" = key-dim sharded
    # (baseline; all-reduces the intra-chunk A matrices), "seq" = chunk-dim
    # sharded (hillclimbed sequence parallelism; see EXPERIMENTS.md §Perf)
    shard: str = "k"


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str               # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    source: str = ""          # citation for the config

    # --- attention options -------------------------------------------------
    attn_variant: str = "full"        # full | sliding | alternating
    sliding_window: int = 4096
    attn_logit_softcap: float = 0.0   # gemma2: 50.0 (0 disables)
    final_logit_softcap: float = 0.0  # gemma2: 30.0
    qk_norm: bool = False             # chameleon-style query/key RMSNorm
    rope_theta: float = 10000.0
    post_block_norm: bool = False     # gemma2 post-norms
    # decode hillclimb: expand GQA kv heads at attention time so decode
    # logits shard heads over the model axis (cache replicated over model)
    # instead of head-dim sharding (which all-reduces per layer per token)
    decode_expand_kv: bool = False
    # decode hillclimb 2: shard the cache SEQUENCE dim over the model axis —
    # hd contraction stays local; only softmax partials and the (B,H,hd)
    # context all-reduce cross shards
    decode_cache_seq: bool = False

    # --- per-family sub-configs --------------------------------------------
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None

    # hybrid (zamba2): rounds of `hybrid_period` ssm blocks followed by one
    # weight-shared attention block.
    hybrid_period: int = 0

    # deepseek: number of leading dense (non-MoE) layers
    first_k_dense: int = 0
    # deepseek multi-token prediction depth (0 disables)
    mtp_depth: int = 0

    # modality frontend stub: inputs carry `prefix_embeds` of shape
    # (batch, prefix_len, d_model) produced by a frozen external encoder.
    prefix_frontend: bool = False
    prefix_len: int = 0

    tie_embeddings: bool = False
    scale_embeddings: bool = False    # gemma: embed * sqrt(d_model)
    norm_eps: float = 1e-5

    def with_overrides(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- derived -----------------------------------------------------------
    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(self.num_kv_heads, 1)

    def param_count(self) -> int:
        """Analytic parameter count (matches init exactly; used for roofline
        MODEL_FLOPS and memory planning)."""
        from repro.models.backbone import count_params_analytic

        return count_params_analytic(self)

    def active_param_count(self) -> int:
        from repro.models.backbone import count_params_analytic

        return count_params_analytic(self, active_only=True)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                 # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


@dataclass(frozen=True)
class FederatedConfig:
    """The paper's technique as a first-class training feature.

    num_silos `d` intra-group DC servers run `local_steps` optimizer steps
    with zero cross-silo communication, then average parameters across the
    silo mesh axis (the central-FL-server all-reduce). local_steps=1 with
    num_silos=1 degenerates to standard data-parallel training.
    """

    num_silos: int = 1
    local_steps: int = 4              # H — paper: epochs-per-round
    # fedavg | fedprox | fedsgd, or a robust boundary (DESIGN.md §8):
    # median | trimmed_mean | krum
    aggregator: str = "fedavg"
    fedprox_mu: float = 0.0
    trim_frac: float = 0.2            # trimmed_mean: trim fraction per tail
    krum_f: int = 1                   # krum: tolerated Byzantine silos
    # silo mesh axis is resolved at launch: "pod" (multi-pod) or "data".
    silo_axis: str = "auto"


@dataclass(frozen=True)
class TrainConfig:
    model: ModelConfig
    shape: InputShape
    federated: FederatedConfig = field(default_factory=FederatedConfig)
    learning_rate: float = 3e-4
    weight_decay: float = 0.1
    warmup_steps: int = 100
    total_steps: int = 1000
    grad_clip: float = 1.0
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    optimizer: str = "adamw"
    opt_state_dtype: str = "float32"  # bf16 for very large models
    remat: bool = True
    seed: int = 0
    fsdp: bool = True                 # shard params over the data axis too


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", seq_len=4096, global_batch=256, kind="train"),
    "prefill_32k": InputShape("prefill_32k", seq_len=32768, global_batch=32, kind="prefill"),
    "decode_32k": InputShape("decode_32k", seq_len=32768, global_batch=128, kind="decode"),
    "long_500k": InputShape("long_500k", seq_len=524288, global_batch=1, kind="decode"),
}
