"""Architecture registry: ``--arch <id>`` resolution.

ARCHS maps the public arch id to its ModelConfig; REDUCED maps to a smoke-test
variant of the same family (<=2 layers, d_model<=512, <=4 experts) runnable on
CPU.
"""
from __future__ import annotations

import dataclasses

from repro.configs.base import (
    INPUT_SHAPES,
    FederatedConfig,
    InputShape,
    MLAConfig,
    ModelConfig,
    MoEConfig,
    SSMConfig,
    TrainConfig,
)

from repro.configs import (  # noqa: E402
    chameleon_34b,
    deepseek_v3_671b,
    gemma2_2b,
    glm4_9b,
    granite_moe_1b,
    llama3_2_1b,
    musicgen_large,
    rwkv6_3b,
    starcoder2_15b,
    zamba2_1_2b,
)

ARCHS = {
    "llama3.2-1b": llama3_2_1b.CONFIG,
    "gemma2-2b": gemma2_2b.CONFIG,
    "starcoder2-15b": starcoder2_15b.CONFIG,
    "rwkv6-3b": rwkv6_3b.CONFIG,
    "granite-moe-1b-a400m": granite_moe_1b.CONFIG,
    "musicgen-large": musicgen_large.CONFIG,
    "deepseek-v3-671b": deepseek_v3_671b.CONFIG,
    "glm4-9b": glm4_9b.CONFIG,
    "zamba2-1.2b": zamba2_1_2b.CONFIG,
    "chameleon-34b": chameleon_34b.CONFIG,
}


def get_arch(name: str) -> ModelConfig:
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; choose from {sorted(ARCHS)}")
    return ARCHS[name]


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant of the same family: 2 layers, d_model<=512,
    <=4 experts, tiny vocab — runs a real forward/train step on CPU."""
    kw: dict = dict(
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=min(cfg.num_kv_heads, 4) if cfg.num_kv_heads < cfg.num_heads else 4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        prefix_len=8 if cfg.prefix_frontend else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe,
            num_experts=4,
            top_k=2,
            d_ff_expert=128,
            d_ff_shared=128 if cfg.moe.num_shared_experts else 0,
            impl="dense",
        )
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(
            q_lora_rank=64, kv_lora_rank=32, qk_nope_head_dim=32,
            qk_rope_head_dim=16, v_head_dim=32,
        )
        kw["head_dim"] = 32
    if cfg.ssm is not None:
        kw["ssm"] = dataclasses.replace(cfg.ssm, state_dim=16, head_dim=32, chunk=16)
        kw["num_heads"] = 8 if cfg.ssm.kind == "rwkv6" else kw["num_heads"]
        kw["num_kv_heads"] = kw["num_heads"]
    if cfg.hybrid_period:
        kw["num_layers"] = 3          # 2 mamba + shared-attn cadence of 2
        kw["hybrid_period"] = 2
    if cfg.first_k_dense:
        kw["first_k_dense"] = 1
        kw["num_layers"] = 2          # 1 dense + 1 moe
    return cfg.with_overrides(name=cfg.name + "-smoke", **kw)


REDUCED = {name: reduced(cfg) for name, cfg in ARCHS.items()}

__all__ = [
    "ARCHS", "REDUCED", "INPUT_SHAPES", "get_arch", "reduced",
    "ModelConfig", "MoEConfig", "MLAConfig", "SSMConfig",
    "InputShape", "FederatedConfig", "TrainConfig",
]
