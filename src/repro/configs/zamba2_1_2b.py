"""zamba2-1.2b — Mamba2 backbone + periodically applied weight-shared
attention block. [arXiv:2411.15242]

38 Mamba2 blocks; after every 6th block the single shared attention+MLP block
(one parameter set, reused) is applied — 6 shared applications total, trailing
2 Mamba2 blocks. ssm_state=64.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,                      # shared block MLP width
    vocab_size=32000,
    ssm=SSMConfig(kind="mamba2", state_dim=64, head_dim=64, expand=2, conv_dim=4, chunk=128),
    hybrid_period=6,
    source="arXiv:2411.15242",
)
