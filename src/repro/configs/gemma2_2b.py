"""gemma2-2b — local/global alternating attention + logit softcaps. [arXiv:2408.00118]"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-2b",
    family="dense",
    num_layers=26,
    d_model=2304,
    num_heads=8,
    num_kv_heads=4,
    head_dim=256,
    d_ff=9216,
    vocab_size=256000,
    attn_variant="alternating",       # even layers local (sliding), odd global
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_block_norm=True,
    tie_embeddings=True,
    scale_embeddings=True,
    source="arXiv:2408.00118",
)
