"""chameleon-34b — early-fusion VLM over VQ image tokens. [arXiv:2405.09818]

The VQ-VAE image tokenizer / patch encoder is a STUB per the brief:
input_specs() supplies precomputed patch embeddings (batch, prefix_len,
d_model); text+image VQ tokens share the 65536 vocab. qk-norm per the paper.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    qk_norm=True,
    prefix_frontend=True,
    prefix_len=256,
    source="arXiv:2405.09818",
)
