"""rwkv6-3b (Finch) — attention-free, data-dependent decay. [arXiv:2404.05892]"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,                 # d_model / head_dim time-mix heads
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    # chunk=16: fp32 stability domain of the chunked factored WKV6 form
    ssm=SSMConfig(kind="rwkv6", state_dim=64, head_dim=64, chunk=16),
    source="arXiv:2404.05892",
)
