"""repro.analysis tier (DESIGN.md §9): the linter and the HLO auditor.

Every lint rule gets a minimal fixture that triggers it EXACTLY once plus
a clean twin encoding the approved pattern, the disable directives are
exercised both ways, the CLI is driven as a subprocess (including the
repo-wide run, which must be clean), and the compiled-artifact layer is
pinned: census counts bit-identical to the historical inline regex,
baked-constant detection with a closure-baked positive control, and the
CompileCounter recompile sentinel.
"""
import json
import re
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import RULES, lint_source, violations_json
from repro.analysis.hlo_audit import (COLLECTIVE_KINDS, BakedDataError,
                                      CompileCounter, assert_no_baked_data,
                                      collective_census, find_baked_constants)

REPO = "/root/repo"


def _lint(src):
    return lint_source(textwrap.dedent(src))


# one (bad, good) pair per rule: bad fires the rule exactly once, good is
# the approved pattern for the same job and fires nothing
FIXTURES = {
    "R001": (
        """
        import time
        t0 = time.time()
        """,
        """
        import time
        t0 = time.perf_counter()
        """,
    ),
    "R002": (
        """
        seed = hash("silo-3") % 2**31
        """,
        """
        import zlib
        seed = zlib.crc32(b"silo-3") % 2**31
        """,
    ),
    "R003": (
        """
        import numpy as np
        x = np.random.standard_normal(4)
        """,
        """
        import numpy as np
        x = np.random.default_rng(0).standard_normal(4)
        """,
    ),
    "R004": (
        """
        import jax
        import jax.numpy as jnp
        data = jnp.asarray([[1.0, 2.0]])

        @jax.jit
        def f(p):
            return (data * p).sum()
        """,
        """
        import jax
        import jax.numpy as jnp
        data = jnp.asarray([[1.0, 2.0]])

        @jax.jit
        def f(p, d):
            return (d * p).sum()

        out = f(2.0, data)
        """,
    ),
    "R005": (
        """
        import numpy as np
        sizes = np.asarray([10, 20])
        w = sizes.astype(np.float32)
        """,
        """
        import numpy as np
        sizes = np.asarray([10, 20])
        w = (sizes / sizes.sum()).astype(np.float32)
        """,
    ),
    "R006": (
        """
        import jax.numpy as jnp

        def norm(weights):
            return weights / jnp.sum(weights)
        """,
        """
        import jax.numpy as jnp

        def norm(weights):
            return weights / jnp.maximum(jnp.sum(weights), 1e-12)
        """,
    ),
    "R007": (
        """
        import numpy as np

        def save(path, arr):
            np.savez(path, arr=arr)
        """,
        """
        import os
        import tempfile
        import numpy as np

        def save(path, arr):
            fd, tmp = tempfile.mkstemp(suffix=".npz")
            with os.fdopen(fd, "wb") as f:
                np.savez(f, arr=arr)
            os.replace(tmp, path)
        """,
    ),
    "R008": (
        """
        import jax

        def drive(plan, args, rounds):
            for rnd in range(rounds):
                out = jax.device_get(plan(*args))
            return out
        """,
        """
        import jax

        def drive(plan, args, rounds):
            for rnd in range(rounds):
                out = plan(*args)
            return jax.device_get(out)
        """,
    ),
}


# ---------------------------------------------------------------------------
# rules: each fixture fires exactly once; its clean twin not at all
# ---------------------------------------------------------------------------

def test_fixture_set_covers_every_rule():
    assert set(FIXTURES) == set(RULES)


@pytest.mark.parametrize("rule", sorted(FIXTURES))
def test_rule_fires_exactly_once_on_fixture(rule):
    bad, good = FIXTURES[rule]
    vs = _lint(bad)
    assert [v.rule for v in vs] == [rule], (rule, [v.format() for v in vs])
    assert vs[0].line > 0 and vs[0].snippet
    assert _lint(good) == [], (rule, [v.format() for v in _lint(good)])


def test_r004_jit_call_and_lambda_forms():
    base = ("import jax\n"
            "import jax.numpy as jnp\n"
            "data = jnp.asarray([[1.0, 2.0]])\n")
    for form in ("g = jax.jit(lambda p: (data * p).sum())\n",
                 "def f(p):\n"
                 "    return (data * p).sum()\n"
                 "g = jax.jit(f)\n"):
        vs = lint_source(base + form)
        assert [v.rule for v in vs] == ["R004"], (form,
                                                  [v.format() for v in vs])


def test_r006_flags_oversized_clamp():
    vs = _lint("""
    import jax.numpy as jnp

    def norm(mask):
        return mask / jnp.maximum(jnp.sum(mask), 1.0)
    """)
    assert [v.rule for v in vs] == ["R006"]
    assert "deflates" in vs[0].message


def test_syntax_error_reported_not_raised():
    vs = lint_source("def broken(:\n")
    assert len(vs) == 1 and vs[0].rule == "E000"


# ---------------------------------------------------------------------------
# disable directives: trailing, preceding-line, file-level, wrong-rule
# ---------------------------------------------------------------------------

def test_disable_trailing_and_preceding_line():
    bad, _ = FIXTURES["R001"]
    lines = textwrap.dedent(bad).strip().splitlines()
    trailing = "\n".join(
        ln + "  # feddcl-lint: disable=R001  fixture" if "time.time" in ln
        else ln for ln in lines)
    assert lint_source(trailing) == []
    preceding = "\n".join(
        f"# feddcl-lint: disable=R001  fixture\n{ln}" if "time.time" in ln
        else ln for ln in lines)
    assert lint_source(preceding) == []


def test_disable_file_level_and_wrong_rule():
    bad, _ = FIXTURES["R003"]
    assert lint_source("# feddcl-lint: disable-file=R003  fixture\n"
                       + textwrap.dedent(bad)) == []
    # disabling a DIFFERENT rule must not silence the violation
    survived = lint_source("# feddcl-lint: disable-file=R001  fixture\n"
                           + textwrap.dedent(bad))
    assert [v.rule for v in survived] == ["R003"]


def test_violations_json_shape():
    vs = _lint(FIXTURES["R001"][0])
    doc = json.loads(violations_json(vs, files_checked=1))
    assert doc["tool"] == "feddcl_lint"
    assert doc["violation_count"] == 1 and doc["files_checked"] == 1
    assert doc["violations"][0]["rule"] == "R001"
    assert set(doc["rules"]) == set(RULES)


# ---------------------------------------------------------------------------
# the CLI as users run it (stdlib-only: no jax import in the subprocess)
# ---------------------------------------------------------------------------

def _cli(*argv, cwd=REPO):
    return subprocess.run(
        [sys.executable, "scripts/feddcl_lint.py", *argv],
        capture_output=True, text=True, timeout=120, cwd=cwd,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"})


def test_cli_nonzero_and_json_on_each_rule_fixture(tmp_path):
    for rule, (bad, _) in sorted(FIXTURES.items()):
        f = tmp_path / f"{rule.lower()}_fixture.py"
        f.write_text(textwrap.dedent(bad))
        r = _cli(str(f), "--json")
        assert r.returncode == 1, (rule, r.stdout, r.stderr)
        doc = json.loads(r.stdout)
        assert [v["rule"] for v in doc["violations"]] == [rule]


def test_cli_clean_on_this_repo():
    """Satellite (a) pinned: the shipped tree carries zero violations —
    every deliberate exception is allowlisted in-source."""
    r = _cli()
    assert r.returncode == 0, r.stdout + r.stderr
    assert "0 violation(s)" in r.stdout


def test_cli_rules_filter_and_usage_error(tmp_path):
    f = tmp_path / "mixed.py"
    f.write_text(textwrap.dedent(FIXTURES["R001"][0]) +
                 textwrap.dedent(FIXTURES["R003"][0]))
    r = _cli(str(f), "--rules", "R003", "--json")
    assert r.returncode == 1
    assert [v["rule"] for v in json.loads(r.stdout)["violations"]] == ["R003"]
    assert _cli(str(f), "--rules", "R999").returncode == 2


# ---------------------------------------------------------------------------
# collective census: bit-identical to the historical inline counter
# ---------------------------------------------------------------------------

_FAKE_HLO = """
  %ar = f32[4]{0} all-reduce(f32[4]{0} %p), replica_groups={}
  %ars = f32[4]{0} all-reduce-start(f32[4]{0} %q), replica_groups={}
  %ard = f32[4]{0} all-reduce-done(f32[4]{0} %ars)
  %ag = f32[8]{0} all-gather(f32[4]{0} %p), dimensions={0}
  %cp = f32[4]{0} collective-permute(f32[4]{0} %p)
  ROOT %t = tuple(%ar, %ag)
"""


def _inline_histogram(txt):
    # the exact counter tests/test_fed_sharded.py and benchmarks/fed_bench.py
    # used before PR 9 — census must match it token for token
    out = {}
    for kind in ("all-reduce", "all-gather", "all-to-all",
                 "collective-permute", "reduce-scatter"):
        n = len(re.findall(rf"= \S+ {kind}(?:-start)?\(", txt))
        if n:
            out[kind] = n
    return out


def test_census_matches_inline_regex_on_synthetic_hlo():
    want = _inline_histogram(_FAKE_HLO)
    assert want == {"all-reduce": 2, "all-gather": 1,
                    "collective-permute": 1}     # -done NOT double-counted
    assert collective_census(_FAKE_HLO) == want
    assert set(COLLECTIVE_KINDS) == {"all-reduce", "all-gather", "all-to-all",
                                     "collective-permute", "reduce-scatter"}


def test_census_accepts_lowered_and_single_device_is_empty():
    low = jax.jit(lambda x: (x @ x.T).sum()).lower(
        jnp.zeros((8, 8), jnp.float32))
    assert collective_census(low) == {}
    assert collective_census(low.compile()) == {}


# ---------------------------------------------------------------------------
# baked-data audit: splats pass, captured tenant data fails
# ---------------------------------------------------------------------------

def test_find_baked_constants_splat_vs_data():
    big = jnp.asarray(np.random.default_rng(0).standard_normal((64, 32)),
                      jnp.float32)
    leaky = jax.jit(lambda p: jnp.sum(big * p)).lower(jnp.float32(1.0))
    found = find_baked_constants(leaky, min_elems=1024)
    assert len(found) == 1 and found[0]["elements"] == 2048
    with pytest.raises(BakedDataError):
        assert_no_baked_data(leaky, min_elems=1024)
    # an equally large SPLAT (zeros) carries no data and must pass
    clean = jax.jit(lambda p: jnp.sum(jnp.zeros((64, 32)) * p)).lower(
        jnp.float32(1.0))
    assert find_baked_constants(clean, min_elems=1024) == []
    assert_no_baked_data(clean, min_elems=1024)
    # below the threshold the same capture is tolerated (tiny tables are
    # legitimate compile-time constants)
    assert find_baked_constants(leaky, min_elems=4096) == []


def test_baked_data_error_is_assertion_error():
    assert issubclass(BakedDataError, AssertionError)


def test_streamed_chunk_plan_audits_clean():
    """The chunked StreamedPlan flavor (the one lower_fl_plan special-cases)
    passes the baked-data audit and, unsharded, holds zero collectives.
    Together with test_fed_robust (unsharded whole-phase, all aggregators)
    and test_fed_sharded (sharded flavors, 8 devices) this completes the
    make_fl_plan flavor matrix of the audit."""
    from repro.core import federated
    from repro.core.federated import lower_fl_plan, pad_silo_data
    from repro.models import mlp
    from repro.optim import adamw

    rng = np.random.default_rng(0)
    wt = rng.standard_normal((8, 1))
    silos = []
    for n in (24, 17, 20):
        X = rng.standard_normal((n, 8))
        silos.append((X, X @ wt + 0.01 * rng.standard_normal((n, 1))))
    params = mlp.init_mlp_params(jax.random.PRNGKey(0), 8, (8,), 1)
    loss = lambda p, x, y: mlp.mlp_per_example_loss(p, x, y, "regression")
    bl = federated._make_batch_loss(loss, True, 0.0)
    padded = pad_silo_data(silos, 8)
    plan = federated.make_fl_plan(
        num_silos=padded.num_silos, num_batches=padded.num_batches,
        batch_size=padded.batch_size, opt=adamw(1e-2), batch_loss=bl,
        rounds=4, local_epochs=1, aggregator="fedavg", masked=True,
        collect="chunk")
    lowered = lower_fl_plan(plan, params, padded, rounds=4)
    assert_no_baked_data(lowered, min_elems=256)
    assert collective_census(lowered) == {}


# ---------------------------------------------------------------------------
# CompileCounter: counts executable builds, not cache hits
# ---------------------------------------------------------------------------

def test_compile_counter_counts_builds_not_hits():
    f = jax.jit(lambda x: jnp.tanh(x) * 3.0 + x)
    x = jnp.arange(24.0).reshape(4, 6)
    with CompileCounter() as cold:
        f(x).block_until_ready()
    assert cold.count >= 1
    with CompileCounter() as warm:
        f(x).block_until_ready()
    assert warm.count == 0
    with CompileCounter() as reshaped:               # new shape recompiles
        f(jnp.arange(12.0).reshape(3, 4)).block_until_ready()
    assert reshaped.count >= 1


def test_compile_counter_restores_patch_on_exit():
    import jax._src.compiler as _compiler

    before = _compiler.backend_compile
    with CompileCounter():
        assert _compiler.backend_compile is not before
    assert _compiler.backend_compile is before
