"""Roofline extraction machinery: HLO collective parsing, replica-group
decoding (explicit + iota forms), cross-boundary classification, and term
arithmetic — the §Roofline numbers are only as good as this parser."""
import numpy as np
import pytest

from repro.launch import roofline as R

HLO = """
HloModule test
ENTRY %main {
  %p0 = bf16[128,512]{1,0} parameter(0)
  %ar = bf16[128,512]{1,0} all-reduce(%p0), replica_groups=[4,2]<=[8], to_apply=%add
  %ag = f32[64,32]{1,0} all-gather(%ar), replica_groups={{0,1,2,3},{4,5,6,7}}, dimensions={0}
  %a2a = f32[16,16]{1,0} all-to-all(%ag), replica_groups=[2,4]<=[4,2]T(1,0)
  %rs-start = bf16[8,8]{1,0} reduce-scatter(%a2a), replica_groups={}
  %done = bf16[8,8]{1,0} all-reduce-done(%rs-start)
}
"""


def test_collective_bytes_sums_result_shapes():
    out = R.collective_bytes(HLO)
    assert out["all-reduce"] == 128 * 512 * 2
    assert out["all-gather"] == 64 * 32 * 4
    assert out["all-to-all"] == 16 * 16 * 4
    assert out["reduce-scatter"] == 8 * 8 * 2
    # -done halves of async pairs are not double counted
    assert out["total"] == sum(v for k, v in out.items() if k != "total")


def test_parse_replica_groups_explicit():
    g = R.parse_replica_groups("{{0,1,2,3},{4,5,6,7}}")
    assert g == [[0, 1, 2, 3], [4, 5, 6, 7]]


def test_parse_replica_groups_iota():
    g = R.parse_replica_groups("[4,2]<=[8]")
    assert g == [[0, 1], [2, 3], [4, 5], [6, 7]]


def test_parse_replica_groups_iota_transposed():
    g = R.parse_replica_groups("[2,4]<=[4,2]T(1,0)")
    # arange(8).reshape(4,2).T = [[0,2,4,6],[1,3,5,7]] -> reshape (2,4)
    assert g == [[0, 2, 4, 6], [1, 3, 5, 7]]


def test_parse_replica_groups_empty_means_all():
    assert R.parse_replica_groups("{}", num_devices=4) == [[0, 1, 2, 3]]


def test_cross_block_bytes_classification():
    # block=2: the [4,2] iota groups {0,1},{2,3}.. stay inside blocks;
    # the explicit {0,1,2,3} group crosses them.
    xb = R.cross_block_bytes(HLO, block=2, num_devices=8)
    assert xb >= 64 * 32 * 4                      # the all-gather crosses
    within = R.cross_block_bytes(HLO, block=8, num_devices=8)
    assert within == 0                            # nothing crosses one big block


def test_model_flops_kinds():
    from repro.configs import ARCHS, INPUT_SHAPES
    cfg = ARCHS["llama3.2-1b"]
    tr = R.model_flops(cfg, INPUT_SHAPES["train_4k"], "train")
    de = R.model_flops(cfg, INPUT_SHAPES["decode_32k"], "decode")
    pf = R.model_flops(cfg, INPUT_SHAPES["prefill_32k"], "prefill")
    assert tr > pf > de > 0
    # train = 6·N·D, prefill = 2·N·D at the same token count would be 3×;
    # the shapes differ in tokens so just check the 6/2 structure per token
    tok_tr = 256 * 4096
    tok_pf = 32 * 32768
    assert abs((tr / tok_tr) / (pf / tok_pf) - 3.0) < 1e-6


def test_hw_constants_prescribed():
    assert R.HW == {"peak_flops": 197e12, "hbm_bw": 819e9, "link_bw": 50e9}
