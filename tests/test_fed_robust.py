"""Hostile-world federation tier (DESIGN.md §8): robust aggregators
(median / trimmed_mean / krum) must agree across engines, survive the
attacker harness that breaks plain fedavg, and compose with silo-dropout
schedules — plus unit pins for the masked statistics, the attack builders,
and the tiny-eps loss denominator fix."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federated, privacy
from repro.core.federated import (AGGREGATORS, ROBUST_AGGREGATORS,
                                  apply_silo_scale, krum_select,
                                  make_dropout_schedule, masked_median,
                                  masked_trimmed_mean, robust_aggregate,
                                  robust_sync, run_federated)
from repro.models import mlp
from repro.optim import adamw


def _reg_loss(p, x, y):
    return mlp.mlp_per_example_loss(p, x, y, "regression")


def _linear_silos(sizes, m=4, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, 1))
    out = []
    for k, n in enumerate(sizes):
        r = np.random.default_rng(seed * 97 + k + 1)
        X = r.standard_normal((n, m))
        out.append((X, X @ w + 0.01 * r.standard_normal((n, 1))))
    return out


def _params(m=4, out=1, seed=0):
    return mlp.init_mlp_params(jax.random.PRNGKey(seed), m, (8,), out)


def _max_abs_diff(a, b):
    return max(float(np.max(np.abs(np.asarray(x) - np.asarray(y))))
               for x, y in zip(jax.tree_util.tree_leaves(a),
                               jax.tree_util.tree_leaves(b)))


# --------------------------------------------------------------------------
# masked statistics: unit pins against numpy
# --------------------------------------------------------------------------

def test_masked_median_matches_numpy():
    rng = np.random.default_rng(0)
    v = rng.standard_normal((6, 3, 2)).astype(np.float32)
    mask = np.array([1, 1, 0, 1, 1, 1], np.float32)
    got = np.asarray(masked_median(jnp.asarray(v), jnp.asarray(mask)))
    want = np.median(v[mask > 0], axis=0)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_masked_trimmed_mean_matches_numpy():
    rng = np.random.default_rng(1)
    v = rng.standard_normal((7, 5)).astype(np.float32)
    mask = np.array([1, 1, 1, 0, 1, 1, 0], np.float32)
    got = np.asarray(masked_trimmed_mean(jnp.asarray(v), jnp.asarray(mask),
                                         0.2))
    sub = np.sort(v[mask > 0], axis=0)           # k=5, trim floor(5*.2)=1
    want = sub[1:-1].mean(0)
    np.testing.assert_allclose(got, want, atol=1e-6)


def test_trimmed_mean_trim_clamped_to_survivor():
    """trim_frac large enough to trim everything must leave the middle
    value, not index out of range / divide by zero."""
    v = jnp.asarray([[1.0], [2.0], [100.0]])
    got = np.asarray(masked_trimmed_mean(v, jnp.ones((3,)), 0.49))
    np.testing.assert_allclose(got, [2.0], atol=1e-6)


def test_krum_selects_inside_honest_cluster():
    rng = np.random.default_rng(2)
    honest = rng.standard_normal((5, 8)).astype(np.float32) * 0.1
    outlier = np.full((1, 8), 50.0, np.float32)
    flat = jnp.asarray(np.concatenate([honest, outlier]))     # (d=6, P=8)
    idx = int(krum_select(flat, jnp.ones((6,)), 1))
    assert idx < 5                                           # never the outlier


def test_robust_aggregate_ignores_masked_outlier():
    """A masked-out silo must not move any robust statistic at all."""
    rng = np.random.default_rng(3)
    tree = {"w": rng.standard_normal((4, 3, 2)).astype(np.float32),
            "b": rng.standard_normal((4, 2)).astype(np.float32)}
    poisoned = jax.tree.map(lambda a: np.concatenate(
        [a, np.full((1,) + a.shape[1:], 1e6, np.float32)]), tree)
    m_clean = jnp.ones((4,))
    m_pois = jnp.asarray([1, 1, 1, 1, 0], jnp.float32)
    for agg in ROBUST_AGGREGATORS:
        clean = robust_aggregate(jax.tree.map(jnp.asarray, tree),
                                 m_clean, agg)
        masked = robust_aggregate(jax.tree.map(jnp.asarray, poisoned),
                                  m_pois, agg)
        assert _max_abs_diff(clean, masked) < 1e-6, agg


def test_apply_silo_scale_is_exact_noop_at_one():
    rng = np.random.default_rng(4)
    ref = {"w": rng.standard_normal((3, 2)).astype(np.float32)}
    sp = {"w": rng.standard_normal((5, 3, 2)).astype(np.float32)}
    out = apply_silo_scale(jax.tree.map(jnp.asarray, sp),
                           jax.tree.map(jnp.asarray, ref),
                           jnp.ones((5,)))
    assert np.array_equal(np.asarray(out["w"]), sp["w"])     # bit-exact


def test_robust_sync_broadcast_and_fallback():
    rng = np.random.default_rng(5)
    sp = {"w": jnp.asarray(rng.standard_normal((5, 4)).astype(np.float32))}
    out = robust_sync(sp, "median")
    # every silo restarts from the same point, and it is the median
    assert np.allclose(np.asarray(out["w"]),
                       np.median(np.asarray(sp["w"]), 0)[None])
    fb = robust_sync(sp, "fedavg")
    assert np.allclose(np.asarray(fb["w"]),
                       np.mean(np.asarray(sp["w"]), 0)[None], atol=1e-6)


# --------------------------------------------------------------------------
# dropout schedule + round weights
# --------------------------------------------------------------------------

def test_dropout_schedule_shape_and_liveness():
    av = make_dropout_schedule(0, rounds=50, num_silos=5, rate=0.5)
    assert av.shape == (50, 5) and av.dtype == np.float32
    assert set(np.unique(av)) <= {0.0, 1.0}
    assert np.all(av.sum(1) >= 1)          # no dead rounds, ever
    assert 0.2 < av.mean() < 0.8           # actually drops some silos


def test_dropout_schedule_empty_silos_never_available():
    sizes = np.array([10, 0, 7], np.float64)
    av = make_dropout_schedule(1, rounds=30, num_silos=3, rate=0.3,
                               sizes=sizes)
    assert np.all(av[:, 1] == 0.0)
    assert np.all(av.sum(1) >= 1)


def test_dropout_schedule_deterministic():
    a = make_dropout_schedule(7, 20, 4, 0.4)
    b = make_dropout_schedule(7, 20, 4, 0.4)
    assert np.array_equal(a, b)
    c = make_dropout_schedule(8, 20, 4, 0.4)
    assert not np.array_equal(a, c)


def test_round_weights_no_dropout_matches_norm_weights():
    sizes = np.array([40.0, 28.0, 52.0])
    wr = federated._round_weights(sizes, None, rounds=3)
    wn = federated._norm_weights(sizes)
    assert wr.shape == (3, 3)
    for r in range(3):
        assert np.array_equal(wr[r], wn)   # bit-identical, not just close


def test_round_weights_renormalize_over_present():
    sizes = np.array([10.0, 30.0, 60.0])
    av = np.array([[1, 0, 1], [1, 1, 1]], np.float32)
    wr = federated._round_weights(sizes, av, rounds=2)
    np.testing.assert_allclose(wr[0], [10 / 70, 0.0, 60 / 70], atol=1e-7)
    np.testing.assert_allclose(wr[1], [0.1, 0.3, 0.6], atol=1e-7)


# --------------------------------------------------------------------------
# attacker harness (core/privacy.py)
# --------------------------------------------------------------------------

def test_label_flip_silos_classification_and_regression():
    data = [(np.zeros((4, 2)), np.array([0, 1, 2, 2])),
            (np.zeros((3, 2)), np.array([[1.0], [-2.0], [3.0]]))]
    flipped = privacy.label_flip_silos(data, [0], num_classes=3)
    assert np.array_equal(flipped[0][1], [1, 2, 0, 0])
    assert flipped[1][1] is data[1][1]             # honest silo: no copy
    neg = privacy.label_flip_silos(data, [1])
    assert np.array_equal(neg[1][1], -data[1][1])


def test_grad_scale_vector_and_validation():
    v = privacy.grad_scale_vector(4, [1, 3], scale=-5.0)
    np.testing.assert_allclose(v, [1.0, -5.0, 1.0, -5.0])
    with pytest.raises(ValueError):
        privacy.grad_scale_vector(4, [4])


def test_apply_attack_routes():
    data = [(np.zeros((2, 2)), np.array([[1.0], [2.0]]))] * 3
    d, s = privacy.apply_attack(data, privacy.SiloAttack())
    assert s is None and len(d) == 3
    d, s = privacy.apply_attack(
        data, privacy.SiloAttack(corrupted=(1,), kind="grad_scale",
                                 scale=-3.0))
    assert np.array_equal(d[1][1], data[1][1])     # data untouched
    np.testing.assert_allclose(s, [1.0, -3.0, 1.0])
    d, s = privacy.apply_attack(
        data, privacy.SiloAttack(corrupted=(0,), kind="label_flip"))
    assert s is None and np.array_equal(d[0][1], -data[0][1])
    with pytest.raises(ValueError):
        privacy.SiloAttack(kind="what")


# --------------------------------------------------------------------------
# engine agreement: robust aggregators, dropout, attacks — host == scan
# --------------------------------------------------------------------------

@pytest.mark.parametrize("aggregator", list(ROBUST_AGGREGATORS))
def test_robust_scan_matches_host_ragged(aggregator):
    silos = _linear_silos([40, 28, 52, 33], seed=3)
    params = _params(seed=1)
    kw = dict(opt=adamw(1e-2), rounds=3, local_epochs=2, batch_size=16,
              aggregator=aggregator, seed=7, trim_frac=0.25, krum_f=1)
    host = run_federated(_reg_loss, params, silos, engine="host", **kw)
    scan = run_federated(_reg_loss, params, silos, engine="scan", **kw)
    assert _max_abs_diff(host.params, scan.params) < 1e-4
    for h, s in zip(host.history, scan.history):
        assert abs(h["loss"] - s["loss"]) < 1e-4 * max(1.0, abs(h["loss"]))


@pytest.mark.parametrize("aggregator", ["fedavg", "median"])
def test_dropout_scan_matches_host(aggregator):
    silos = _linear_silos([40, 28, 52], seed=5)
    params = _params(seed=2)
    kw = dict(opt=adamw(1e-2), rounds=4, local_epochs=2, batch_size=16,
              aggregator=aggregator, seed=11, dropout_rate=0.4)
    host = run_federated(_reg_loss, params, silos, engine="host", **kw)
    scan = run_federated(_reg_loss, params, silos, engine="scan", **kw)
    assert _max_abs_diff(host.params, scan.params) < 1e-4


def test_attacked_engines_agree_and_silo_scale_noop():
    """silo_scale threads identically through both engines, and an
    all-ones scale reproduces the unscaled run bit-for-bit."""
    silos = _linear_silos([32, 32, 32], seed=6)
    params = _params(seed=3)
    kw = dict(opt=adamw(1e-2), rounds=3, local_epochs=2, batch_size=16,
              aggregator="median", seed=13)
    scale = [1.0, -3.0, 1.0]
    host = run_federated(_reg_loss, params, silos, engine="host",
                         silo_scale=scale, **kw)
    scan = run_federated(_reg_loss, params, silos, engine="scan",
                         silo_scale=scale, **kw)
    assert _max_abs_diff(host.params, scan.params) < 1e-4
    plain = run_federated(_reg_loss, params, silos, engine="scan", **kw)
    ones = run_federated(_reg_loss, params, silos, engine="scan",
                         silo_scale=[1.0, 1.0, 1.0], **kw)
    assert _max_abs_diff(plain.params, ones.params) == 0.0


# --------------------------------------------------------------------------
# attack efficacy: robust converges where fedavg diverges
# --------------------------------------------------------------------------

def test_grad_scale_attack_breaks_fedavg_not_robust():
    silos = _linear_silos([48, 48, 48, 48, 48], seed=9)
    params = _params(seed=4)
    scale = privacy.grad_scale_vector(5, [2], scale=-5.0)
    kw = dict(opt=adamw(1e-2), rounds=8, local_epochs=2, batch_size=16,
              seed=17, engine="scan", silo_scale=scale)
    fedavg = run_federated(_reg_loss, params, silos, aggregator="fedavg",
                           **kw)
    med = run_federated(_reg_loss, params, silos, aggregator="median", **kw)
    clean = run_federated(_reg_loss, params, silos, aggregator="fedavg",
                          opt=adamw(1e-2), rounds=8, local_epochs=2,
                          batch_size=16, seed=17, engine="scan")
    bad = fedavg.history[-1]["loss"]
    good = med.history[-1]["loss"]
    ref = clean.history[-1]["loss"]
    assert good <= 0.5 * bad               # the ISSUE acceptance bound
    assert good <= 2.0 * ref + 0.1         # robust ~ clean, not merely < bad


def test_label_flip_attack_robust_beats_fedavg():
    """Data poisoning: judge the FINAL GLOBAL MODEL on honest data — the
    reported round loss averages in the corrupted silo's own (unfittable)
    objective, which masks the damage to everyone else."""
    silos = _linear_silos([48, 48, 48, 48, 48], seed=10)
    flipped = privacy.label_flip_silos(silos, [1])
    params = _params(seed=5)
    kw = dict(opt=adamw(1e-2), rounds=12, local_epochs=2, batch_size=16,
              seed=19, engine="scan")
    Xh = jnp.asarray(np.concatenate(
        [x for i, (x, _) in enumerate(silos) if i != 1]), jnp.float32)
    Yh = jnp.asarray(np.concatenate(
        [y for i, (_, y) in enumerate(silos) if i != 1]), jnp.float32)

    def honest_loss(p):
        return float(jnp.mean(_reg_loss(p, Xh, Yh)))

    fedavg = run_federated(_reg_loss, params, flipped, aggregator="fedavg",
                           **kw)
    tm = run_federated(_reg_loss, params, flipped,
                       aggregator="trimmed_mean", trim_frac=0.25, **kw)
    assert honest_loss(tm.params) <= 0.5 * honest_loss(fedavg.params)


# --------------------------------------------------------------------------
# tiny-eps denominator (satellite: the max(Σw, 1) deflation fix)
# --------------------------------------------------------------------------

def test_batch_loss_fractional_weights_not_deflated():
    """Pin the corrected denominator: with uniform fractional weights the
    masked batch loss must equal the plain mean — the old max(Σw, 1) clamp
    silently divided by 1 whenever the real weight mass was < 1."""
    params = _params(m=2, seed=6)
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((2, 2)).astype(np.float32))
    y = jnp.asarray(rng.standard_normal((2, 1)).astype(np.float32))
    bl = federated._make_batch_loss(_reg_loss, True, 0.0)
    frac = float(bl(params, x, y, jnp.full((2,), 0.25), params))
    unit = float(bl(params, x, y, jnp.ones((2,)), params))
    # Σw = 0.5: old clamp would report frac == unit/2; fixed: equal means
    assert abs(frac - unit) < 1e-6 * max(1.0, abs(unit))
    assert frac > 0.0


def test_registry_contains_all_aggregators():
    assert set(ROBUST_AGGREGATORS) == {"median", "trimmed_mean", "krum"}
    assert set(AGGREGATORS) >= {"fedavg", "fedprox", "fedsgd"} | \
        set(ROBUST_AGGREGATORS)
    with pytest.raises(ValueError):
        run_federated(_reg_loss, _params(), _linear_silos([8]),
                      opt=adamw(1e-2), rounds=1, local_epochs=1,
                      batch_size=8, aggregator="fedfoo")


# --------------------------------------------------------------------------
# compiled-plan structure (repro.analysis): unsharded plans are
# collective-free and never bake tenant data into the executable
# --------------------------------------------------------------------------

@pytest.mark.parametrize("aggregator", ["fedavg", "median", "trimmed_mean",
                                        "krum"])
def test_unsharded_plan_collective_free_and_data_free(aggregator):
    """Without a mesh the whole plan is a single-device program: the
    collective census must be empty (any all-gather/all-reduce here would
    mean the robust boundary leaked shard_map machinery into the vmap
    path), and the lowered module must not embed the silo data."""
    from repro.analysis import assert_no_baked_data, collective_census
    from repro.core.federated import pad_silo_data

    silos = _linear_silos([24, 17, 20], m=8, seed=3)
    params = _params(m=8, seed=3)
    padded = pad_silo_data(silos, 8)
    bl = federated._make_batch_loss(_reg_loss, True, 0.0)
    plan = federated.make_fl_plan(
        num_silos=padded.num_silos, num_batches=padded.num_batches,
        batch_size=padded.batch_size, opt=adamw(1e-2), batch_loss=bl,
        rounds=2, local_epochs=2, aggregator=aggregator, masked=True)
    lowered = plan.lower(params, *federated._plan_args(padded, 0, 2))
    assert collective_census(lowered) == {}
    assert_no_baked_data(lowered, min_elems=256)
