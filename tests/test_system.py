"""End-to-end behaviour: FedDCL beats Local and tracks FedAvg on synthetic
tabular data (the paper's headline result), federated LLM training learns,
and the batched server serves."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.feddcl_mlp import PAPER_MLPS
from repro.core import baselines, protocol
from repro.core.federated import run_federated
from repro.data.partition import split_iid
from repro.data.tabular import make_dataset, train_test_split
from repro.models import mlp
from repro.optim import adamw


@pytest.fixture(scope="module")
def battery():
    cfg = PAPER_MLPS["battery_small"]
    ds = make_dataset("battery_small", n=1500, seed=0)
    (Xtr, Ytr), (Xte, Yte) = train_test_split(ds, 400, 1000, seed=0)
    Xs, Ys = split_iid(Xtr, Ytr, d=2, c=[2, 2], n_ij=100, seed=0)
    return cfg, Xs, Ys, (Xtr, Ytr), (Xte, Yte)


def test_feddcl_comparable_to_fedavg_better_than_local(battery):
    """Experiment-I relative ordering: FedDCL ≈ FedAvg ≪ Local (RMSE)."""
    cfg, Xs, Ys, (Xtr, Ytr), (Xte, Yte) = battery
    key = jax.random.PRNGKey(0)
    # per-example loss: silo sizes (100/200) aren't batch multiples, so the
    # engine zero-pads and masks (core/federated.py)
    loss = lambda p, x, y: mlp.mlp_per_example_loss(p, x, y, "regression")

    # Local
    p = mlp.for_config(key, cfg, reduced=False)
    p, _ = baselines.sgd_train(loss, p, Xs[0][0], Ys[0][0], opt=adamw(1e-3),
                               epochs=25)
    rmse_local = mlp.mlp_metric(p, jnp.asarray(Xte), jnp.asarray(Yte),
                                "regression")

    # FedAvg
    p = mlp.for_config(key, cfg, reduced=False)
    flat = [(Xs[i][j], Ys[i][j]) for i in range(2) for j in range(2)]
    res = run_federated(loss, p, flat, opt=adamw(1e-3), rounds=12,
                        local_epochs=3)
    rmse_fedavg = mlp.mlp_metric(res.params, jnp.asarray(Xte),
                                 jnp.asarray(Yte), "regression")

    # FedDCL
    setup = protocol.run_protocol(Xs, Ys, m_tilde=cfg.reduced_dim,
                                  anchor_r=1000, seed=0)
    p = mlp.for_config(key, cfg, reduced=True)
    res = run_federated(loss, p, setup.fed_silos(),
                        opt=adamw(1e-3), rounds=12, local_epochs=3)
    tr = setup.user_transform(0, 0)
    rmse_feddcl = mlp.mlp_metric(res.params, jnp.asarray(np.asarray(tr(Xte))),
                                 jnp.asarray(Yte), "regression")

    assert rmse_feddcl < rmse_local, (rmse_feddcl, rmse_local)
    assert rmse_feddcl < 1.5 * rmse_fedavg, (rmse_feddcl, rmse_fedavg)


@pytest.mark.slow
def test_federated_llm_training_learns():
    from repro.launch.train import train
    _, hist = train("llama3.2-1b", reduced=True, steps=24, batch=4, seq=64,
                    silos=2, local_steps=4, lr=3e-3, log_every=4)
    assert hist[-1]["loss"] < hist[0]["loss"]


@pytest.mark.slow
def test_batched_server_serves_and_reuses_slots():
    from repro.configs import REDUCED
    from repro.launch.serve import BatchedServer, Request
    from repro.models import backbone as bb

    cfg = REDUCED["llama3.2-1b"]
    params = bb.init_params(cfg, jax.random.PRNGKey(0), jnp.float32)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=5),
                    max_new=4) for i in range(5)]
    server = BatchedServer(cfg, params, slots=2, cache_len=64)
    outs = server.serve(reqs)
    assert len(outs) == 5
    assert all(len(v) == 4 for v in outs.values())   # 5 reqs > 2 slots -> reuse
