"""Per-architecture smoke tests (deliverable f): REDUCED variant of each
family — one forward + one train step on CPU, asserting shapes and no NaNs;
plus prefill/decode consistency against the full forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, INPUT_SHAPES, REDUCED
from repro.configs.base import InputShape, TrainConfig
from repro.launch import steps as steps_lib
from repro.models import backbone as bb
from repro.models.modality import synthetic_prefix

ARCH_IDS = sorted(REDUCED)


def _batch(cfg, key, B=2, S=32):
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.prefix_frontend:
        batch["prefix_embeds"] = synthetic_prefix(key, cfg, B)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_shapes_and_finite(arch):
    cfg = REDUCED[arch]
    key = jax.random.PRNGKey(0)
    params = bb.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, hidden, aux = bb.forward(params, batch["tokens"], cfg,
                                     prefix_embeds=batch.get("prefix_embeds"),
                                     compute_dtype=jnp.float32)
    T = 32 + (cfg.prefix_len if cfg.prefix_frontend else 0)
    assert logits.shape == (2, T, cfg.vocab_size)
    assert np.all(np.isfinite(np.asarray(logits)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_reduces_loss_and_is_finite(arch):
    cfg = REDUCED[arch]
    key = jax.random.PRNGKey(0)
    shape = InputShape("t", seq_len=32, global_batch=2, kind="train")
    tc = TrainConfig(model=cfg, shape=shape, learning_rate=5e-3, remat=False,
                     warmup_steps=1, total_steps=10, param_dtype="float32",
                     compute_dtype="float32")
    step, opt = steps_lib.make_train_step(cfg, tc)
    step = jax.jit(step)
    params = bb.init_params(cfg, key, jnp.float32)
    opt_state = opt.init(params)
    batch = _batch(cfg, key)
    losses = []
    for _ in range(3):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(l) for l in losses), losses
    assert losses[-1] < losses[0], losses     # same batch -> must descend


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_matches_forward(arch):
    cfg = REDUCED[arch]
    key = jax.random.PRNGKey(0)
    B, S = 2, 24
    params = bb.init_params(cfg, key)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    pe = synthetic_prefix(key, cfg, B) if cfg.prefix_frontend else None
    logits_full, _, _ = bb.forward(params, tokens, cfg, prefix_embeds=pe,
                                   compute_dtype=jnp.float32)
    cache_len = S + (cfg.prefix_len if cfg.prefix_frontend else 0)
    pf_logits, state, next_pos = bb.prefill(
        params, tokens[:, :S - 1], cfg, cache_len=cache_len,
        prefix_embeds=pe, compute_dtype=jnp.float32, cache_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(pf_logits[:, 0]),
                               np.asarray(logits_full[:, -2]),
                               atol=1e-4, rtol=1e-4)
    dec_logits, _ = bb.decode_step(params, state, tokens[:, S - 1:S],
                                   next_pos, cfg, compute_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(dec_logits[:, 0]),
                               np.asarray(logits_full[:, -1]),
                               atol=1e-4, rtol=1e-4)


def test_all_archs_and_shapes_registered():
    assert len(ARCHS) == 10
    assert len(INPUT_SHAPES) == 4
    fams = {cfg.family for cfg in ARCHS.values()}
    assert fams == {"dense", "moe", "ssm", "hybrid", "audio", "vlm"}
    for cfg in REDUCED.values():
        assert cfg.num_layers <= 3 and cfg.d_model <= 512
        if cfg.moe:
            assert cfg.moe.num_experts <= 4


def test_sliding_window_variant_long_context():
    """long_500k policy: sliding variant decodes with a ring cache shorter
    than the sequence."""
    cfg = REDUCED["llama3.2-1b"].with_overrides(attn_variant="sliding",
                                                sliding_window=8)
    key = jax.random.PRNGKey(0)
    params = bb.init_params(cfg, key)
    state = bb.init_decode_state(cfg, 1, cache_len=8, dtype=jnp.float32)
    tok = jnp.zeros((1, 1), jnp.int32)
    for pos in range(20):                     # run far past the window
        logits, state = bb.decode_step(params, state, tok,
                                       jnp.asarray([pos]), cfg,
                                       compute_dtype=jnp.float32)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_gemma2_softcap_bounds_logits():
    cfg = REDUCED["gemma2-2b"]
    key = jax.random.PRNGKey(0)
    params = bb.init_params(cfg, key)
    batch = _batch(cfg, key)
    logits, _, _ = bb.forward(params, batch["tokens"], cfg,
                              compute_dtype=jnp.float32)
    assert float(jnp.max(jnp.abs(logits))) <= cfg.final_logit_softcap + 1e-3
