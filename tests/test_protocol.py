"""Algorithm 1 end-to-end: communication pattern, shapes, privacy, anchors."""
import numpy as np
import pytest

from repro.core import privacy
from repro.core.anchor import make_anchor
from repro.core.mappings import fit_mapping
from repro.core.protocol import finalize_user_models, run_protocol
from repro.data.partition import split_dirichlet, split_iid
from repro.data.tabular import make_dataset, train_test_split


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("battery_small", n=900, seed=0)
    (Xtr, Ytr), _ = train_test_split(ds, 400, 400, seed=0)
    Xs, Ys = split_iid(Xtr, Ytr, d=2, c=[2, 2], n_ij=100, seed=0)
    return run_protocol(Xs, Ys, m_tilde=4, anchor_r=600, seed=0), Xs


def test_user_communicates_exactly_twice(setup):
    st, Xs = setup
    finalize_user_models(st, h=lambda z: z)
    trips = st.comm.user_round_trips()
    assert trips and all(v == 2 for v in trips.values())


def test_no_raw_data_crosses_boundaries(setup):
    st, Xs = setup
    # every payload that leaves a user is dimensionality-reduced (m̃ < m)
    m = Xs[0][0].shape[1]
    for e in st.comm.events:
        if e.src.startswith("user"):
            assert e.payload == "X~,A~,Y"
    assert st.collab_X[0].shape[1] == 4 < m


def test_collab_shapes_and_finiteness(setup):
    st, Xs = setup
    for i, Xc in enumerate(st.collab_X):
        n_i = sum(x.shape[0] for x in Xs[i])
        assert Xc.shape == (n_i, st.m_hat)
        assert np.all(np.isfinite(Xc))


def test_intermediate_reps_vary_but_collab_reps_align(setup):
    """Table 2's qualitative claim: intermediate representations differ in
    scale/orientation across users; collaboration representations are
    mutually consistent (same anchor maps to nearly the same Z rows)."""
    st, Xs = setup
    A = st.anchor
    z = [st.mappings[i][j](A) @ st.Gs[i][j]
         for i in range(2) for j in range(2)]
    base = z[0]
    for other in z[1:]:
        rel = np.linalg.norm(other - base) / np.linalg.norm(base)
        assert rel < 0.35, rel     # approximately incorporable
    inter = [st.mappings[i][j](A) for i in range(2) for j in range(2)]
    rel_inter = np.linalg.norm(inter[1][:, :4] - inter[0][:, :4]) / \
        np.linalg.norm(inter[0][:, :4])
    assert rel_inter > 0.5         # raw intermediates are NOT incorporable


@pytest.mark.parametrize("kind", ["uniform", "lowrank", "smote"])
def test_anchor_kinds(kind):
    rng = np.random.default_rng(0)
    sample = rng.standard_normal((200, 6))
    a = make_anchor(kind, seed=1, r=100,
                    feat_min=sample.min(0), feat_max=sample.max(0),
                    public_sample=sample)
    assert a.shape == (100, 6) and np.all(np.isfinite(a))
    # deterministic in seed (shared anchor property)
    b = make_anchor(kind, seed=1, r=100,
                    feat_min=sample.min(0), feat_max=sample.max(0),
                    public_sample=sample)
    np.testing.assert_array_equal(a, b)


def test_privacy_layers(setup):
    st, Xs = setup
    X = Xs[0][0]
    f = st.mappings[0][0]
    m = privacy.evaluate(X, f)
    # Layer 2: even knowing the map, reconstruction loses the DR tail
    assert m["recovery_error_known_map"] > 0.01
    # Layer 1: without the map, reconstruction is much worse
    assert m["recovery_error_unknown_map"] > 3 * m["recovery_error_known_map"]
    assert 0.0 <= m["eps_dr"] <= 1.0


def test_dirichlet_partition_shapes():
    ds = make_dataset("human_activity", n=3000, seed=0)
    Xs, Ys = split_dirichlet(ds.X, ds.Y, d=3, c=[2, 2, 2], n_ij=100,
                             alpha=0.3, seed=0)
    assert len(Xs) == 3
    for i in range(3):
        for j in range(2):
            assert Xs[i][j].shape == (100, 60)
            assert Ys[i][j].shape == (100,)
    # non-IID: per-user label distributions differ
    p0 = np.bincount(Ys[0][0].astype(int), minlength=5) / 100
    p1 = np.bincount(Ys[1][0].astype(int), minlength=5) / 100
    assert np.abs(p0 - p1).sum() > 0.2
