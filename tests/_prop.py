"""Property-testing shim: use `hypothesis` when installed, otherwise degrade
`@given` strategies to deterministic seeded `pytest.mark.parametrize` cases so
the tier-1 suite collects and runs in a clean environment.

Usage in test modules (instead of importing hypothesis directly):

    from _prop import given, settings, st

The fallback supports exactly the strategy surface the suite uses —
`st.integers`, `st.floats`, `st.sampled_from` — and draws a fixed number of
examples from a fixed-seed generator, so the degraded cases are stable across
runs and machines.
"""
from __future__ import annotations

HAVE_HYPOTHESIS = True
try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
except ImportError:
    HAVE_HYPOTHESIS = False

    import numpy as np
    import pytest

    _FALLBACK_EXAMPLES = 10
    _FALLBACK_SEED = 0xFEDDC1

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class _StrategyNamespace:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: int(rng.integers(min_value,
                                                          max_value + 1)))

        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda rng: float(rng.uniform(min_value,
                                                           max_value)))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))])

    st = _StrategyNamespace()

    def settings(*args, **kwargs):
        """No-op decorator factory (deadline/max_examples are hypothesis
        concerns; the fallback always draws _FALLBACK_EXAMPLES cases)."""
        def deco(fn):
            return fn
        return deco

    def given(**strategies):
        names = sorted(strategies)

        def deco(fn):
            rng = np.random.default_rng(_FALLBACK_SEED)
            cases, seen = [], set()
            for _ in range(_FALLBACK_EXAMPLES):
                case = tuple(strategies[n].draw(rng) for n in names)
                if case not in seen:        # dedupe e.g. small sampled_from
                    seen.add(case)
                    cases.append(case[0] if len(names) == 1 else case)
            return pytest.mark.parametrize(",".join(names), cases)(fn)

        return deco
