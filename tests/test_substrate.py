"""Substrate layers: optimizers, schedules, checkpoint, data pipeline,
sharding policy (property-based), backbone internals."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _prop import given, settings, st

from repro.checkpoint import store
from repro.configs import REDUCED
from repro.data.tabular import PAPER_MLPS, make_dataset
from repro.data.tokens import TokenStream, silo_batches
from repro.models import backbone as bb
from repro.models import layers as L
from repro.optim import adamw, apply_updates, clip_by_global_norm, sgd
from repro.optim.schedules import cosine_with_warmup


# ---------------------------------------------------------------------------
# optim
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("make_opt", [lambda: adamw(0.1),
                                      lambda: sgd(0.05, momentum=0.9)])
def test_optimizer_converges_on_quadratic(make_opt):
    opt = make_opt()
    params = {"x": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(jnp.square(p["x"]))
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-3


def test_adamw_bf16_state_dtype():
    opt = adamw(0.1, state_dtype=jnp.bfloat16)
    params = {"x": jnp.ones((4,))}
    state = opt.init(params)
    assert state["m"]["x"].dtype == jnp.bfloat16
    g = {"x": jnp.ones((4,))}
    upd, state = opt.update(g, state, params)
    assert np.all(np.isfinite(np.asarray(upd["x"])))


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert abs(float(norm) - 20.0) < 1e-4
    total = float(jnp.sqrt(jnp.sum(jnp.square(clipped["a"]))))
    assert abs(total - 1.0) < 1e-4


def test_cosine_schedule_shape():
    f = cosine_with_warmup(1.0, 10, 100)
    assert float(f(jnp.asarray(0))) == 0.0
    assert abs(float(f(jnp.asarray(10))) - 1.0) < 1e-6
    assert float(f(jnp.asarray(100))) < float(f(jnp.asarray(50)))


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    cfg = REDUCED["llama3.2-1b"]
    params = bb.init_params(cfg, jax.random.PRNGKey(0))
    path = str(tmp_path / "ck.npz")
    store.save(path, params, {"arch": cfg.name})
    restored = store.load(path, params)
    for a, b in zip(jax.tree_util.tree_leaves(params),
                    jax.tree_util.tree_leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert store.load_metadata(path)["arch"] == cfg.name


def test_checkpoint_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ck.npz")
    store.save(path, {"w": jnp.ones((2, 2))})
    with pytest.raises(ValueError):
        store.load(path, {"w": jnp.ones((3, 3))})


def test_checkpoint_concurrent_saves_same_path(tmp_path):
    """Concurrent save() calls to ONE path: each writer owns a unique
    mkstemp .npz tmp (the old guess-the-savez-rename dance raced on a
    predictable sibling name), so the surviving checkpoint is one writer's
    intact tree and no tmp litter remains."""
    import threading

    path = str(tmp_path / "ck.npz")
    trees = [{"w": jnp.full((64, 64), float(i))} for i in range(8)]
    errs = []

    def save(i):
        try:
            store.save(path, trees[i], {"i": i})
        except Exception as e:       # pragma: no cover - the assert reports
            errs.append(e)

    threads = [threading.Thread(target=save, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    v = np.asarray(store.load(path, trees[0])["w"])
    assert float(v.min()) == float(v.max())      # one writer won, intact
    assert float(v[0, 0]) == store.load_metadata(path)["i"]
    assert sorted(os.listdir(tmp_path)) == ["ck.npz"]   # no tmp litter


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(PAPER_MLPS))
def test_datasets_match_paper_dims(name):
    ds = make_dataset(name, n=500, seed=0)
    cfg = PAPER_MLPS[name]
    assert ds.X.shape == (500, cfg.in_dim)
    if ds.task == "classification":
        assert set(np.unique(ds.Y)) <= set(range(cfg.out_dim))
    assert np.all(np.isfinite(ds.X))


def test_dataset_fingerprints_pinned():
    """RNG draw-sequence guard: removing tabular.py's dead
    `* sep / sqrt(l) * sqrt(l)` factor consumed no RNG draws, so these
    fingerprints (pinned after the removal) stay stable; any future edit
    that reorders or adds draws shows up here, not in silently shifted
    benchmark numbers."""
    import zlib
    pinned = {"battery_small": (1376729784, 4020745439),
              "mnist": (2658481171, 2230909913)}
    for name, fp in pinned.items():
        ds = make_dataset(name, n=128, seed=7)
        got = (zlib.crc32(np.ascontiguousarray(ds.X).tobytes()),
               zlib.crc32(np.ascontiguousarray(ds.Y).tobytes()))
        assert got == fp, (name, got)


def test_classification_centers_on_sep_sphere():
    """The line after the deleted dead factor projects class centers onto
    the radius-`sep` sphere, which is why the factor was dead: replay the
    rng sequence to recover the centers and check both the projection and
    that the label draw order is unchanged."""
    from repro.data.tabular import _latent_classification
    sep = 2.2
    rng = np.random.default_rng(0)
    _, y = _latent_classification(rng, 200, 10, 4, 3, noise=0.1, sep=sep)
    rng = np.random.default_rng(0)
    np.testing.assert_array_equal(y, rng.integers(0, 3, size=200))
    centers = rng.standard_normal((3, 4))
    centers = centers / np.linalg.norm(centers, axis=1, keepdims=True) * sep
    np.testing.assert_allclose(np.linalg.norm(centers, axis=1), sep,
                               rtol=1e-12)


def test_token_stream_deterministic_and_learnable():
    s = TokenStream(vocab_size=512, seq_len=64, batch_size=4, seed=0)
    b1, b2 = s.batch(3), s.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert b1["tokens"].shape == (4, 64)
    # labels are next tokens
    np.testing.assert_array_equal(b1["labels"][:, :-1], b1["tokens"][:, 1:])


def test_silo_batches_non_iid_differ():
    b = silo_batches(512, 64, 2, 3, step=0, non_iid=True)
    assert b["tokens"].shape == (3, 2, 64)
    assert not np.array_equal(b["tokens"][0], b["tokens"][1])


# ---------------------------------------------------------------------------
# sharding policy (property-based: never emits an indivisible spec)
# ---------------------------------------------------------------------------

@settings(max_examples=25, deadline=None)
@given(arch=st.sampled_from(sorted(REDUCED)))
def test_param_specs_always_divisible(arch):
    import os
    from repro.shardingx.policy import param_specs
    cfg = REDUCED[arch]
    shapes = jax.eval_shape(lambda: bb.init_params(cfg, jax.random.PRNGKey(0)))

    class FakeMesh:
        axis_names = ("data", "model")
        devices = np.empty((4, 2))

    specs = param_specs(shapes, FakeMesh(), fsdp=True)
    sizes = {"data": 4, "model": 2}

    def check(path, leaf, spec):
        for dim, entry in zip(leaf.shape, tuple(spec)):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            prod = int(np.prod([sizes[a] for a in axes]))
            assert dim % prod == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(
        lambda p, l, s: check(p, l, s), shapes, specs,
        is_leaf=lambda x: hasattr(x, "shape"))


# ---------------------------------------------------------------------------
# backbone internals
# ---------------------------------------------------------------------------

def test_chunked_xent_matches_dense():
    cfg = REDUCED["llama3.2-1b"]
    params = bb.init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 4 * bb.XENT_CHUNK
    hidden = jax.random.normal(jax.random.PRNGKey(1), (B, S, cfg.d_model)) * 0.02
    labels = jax.random.randint(jax.random.PRNGKey(2), (B, S), 0, cfg.vocab_size)
    mask = jnp.ones((B, S))
    dense = bb.softmax_xent(bb._lm_logits(params, hidden, cfg), labels, mask)
    chunked = bb.chunked_xent(params, hidden, labels, mask, cfg)
    np.testing.assert_allclose(float(chunked), float(dense), rtol=1e-5)


def test_sdpa_qchunked_matches_reference():
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, H, hd = 1, 4096 + 1024, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    pos = jnp.broadcast_to(jnp.arange(S), (B, S))
    a = L.sdpa(q, k, v, q_pos=pos, k_pos=pos, is_local=False, window=0,
               softcap=0.0)        # chunked (S > threshold, divisible? 5120/1024=5)
    b = L.sdpa_reference(q, k, v, q_pos=pos, k_pos=pos, is_local=False,
                         window=0, softcap=0.0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                               rtol=2e-5)


def test_maybe_scan_unrolled_equivalence():
    xs = jnp.arange(12.0).reshape(4, 3)

    def f(c, x):
        return c + jnp.sum(x), c

    a = L.maybe_scan(f, 0.0, xs)
    with L.unrolled():
        b = L.maybe_scan(f, 0.0, xs)
    np.testing.assert_allclose(float(a[0]), float(b[0]))
    np.testing.assert_allclose(np.asarray(a[1]), np.asarray(b[1]))


def test_mamba_ssd_chunked_vs_naive_scan():
    """ssd_chunked against a direct per-step recurrence."""
    B, S, H, P, N = 1, 40, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    xh = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.3)
    Bc = jax.random.normal(ks[3], (B, S, N))
    Cc = jax.random.normal(jax.random.PRNGKey(9), (B, S, N))
    y_chunk = L.ssd_chunked(xh, dt, A, Bc, Cc, chunk=16)

    state = jnp.zeros((B, H, N, P))
    ys = []
    for t in range(S):
        decay = jnp.exp(dt[:, t] * A[None, :])
        dBx = jnp.einsum("bh,bN,bhp->bhNp", dt[:, t], Bc[:, t], xh[:, t])
        state = state * decay[..., None, None] + dBx
        ys.append(jnp.einsum("bN,bhNp->bhp", Cc[:, t], state))
    y_ref = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_ref),
                               atol=1e-3, rtol=1e-3)
