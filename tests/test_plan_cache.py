"""Plan-cache tier (DESIGN.md §6): compiled executables shared across
tenants.

Warm hits must agree with cold runs, the counters must record exactly the
executables built, distinct configs (aggregator / reset_opt / fedprox_mu)
must never alias onto one plan, and a cached run must agree with the host
engine on the SAME bucketed layout. Also covers the FedDCL.fit() facade
and the persistent XLA compilation cache wiring.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federated
from repro.core.federated import (PlanCache, bucket_pow2, pad_silo_data,
                                  run_federated)
from repro.models import mlp
from repro.optim import adamw

M = 6          # raw feature dim of the toy tenants


def _silos(d, n, seed=0):
    r = np.random.default_rng(seed)
    wt = r.standard_normal((M, 1))
    out = []
    for i in range(d):
        X = r.standard_normal((n + 3 * i, M))            # ragged on purpose
        out.append((X, X @ wt + 0.01 * r.standard_normal((n + 3 * i, 1))))
    return out


def _params(seed=0):
    return mlp.init_mlp_params(jax.random.PRNGKey(seed), M, (8,), 1)


def _loss(p, x, y):
    return mlp.mlp_per_example_loss(p, x, y, "regression")


KW = dict(rounds=2, local_epochs=1, batch_size=8, engine="scan",
          loss_id=("mlp_per_example_loss", "regression"),
          opt_id=("adamw", 1e-2))


def _run(silos, cache, **over):
    kw = {**KW, **over}
    return run_federated(_loss, _params(), silos, opt=adamw(1e-2),
                         cache=cache, **kw)


def _flat(result):
    return np.concatenate(
        [np.ravel(np.asarray(l)) for l in jax.tree.leaves(result.params)])


# ---------------------------------------------------------------------------
# correctness: warm == cold, cached scan == host on the bucketed layout
# ---------------------------------------------------------------------------

def test_warm_hit_agrees_with_cold_run():
    cache = PlanCache()
    first = _run(_silos(3, 20, seed=0), cache)
    assert first.cache_stats["hit"] is False
    tenant = _silos(3, 22, seed=1)           # new tenant, same shape bucket
    warm = _run(tenant, cache)
    assert warm.cache_stats["hit"] is True
    cold = _run(tenant, PlanCache())         # fresh cache: full rebuild
    assert cold.cache_stats["hit"] is False
    np.testing.assert_allclose(_flat(warm), _flat(cold), rtol=1e-6, atol=1e-7)
    assert warm.history[-1]["loss"] == pytest.approx(
        cold.history[-1]["loss"], rel=1e-5)


def test_cached_scan_matches_host_on_bucketed_layout():
    silos = _silos(3, 20, seed=0)
    res = _run(silos, PlanCache())
    bs = KW["batch_size"]
    n_max = max(x.shape[0] for x, _ in silos)
    padded = pad_silo_data(silos, bs,
                           min_batches=bucket_pow2(-(-n_max // bs)),
                           min_silos=bucket_pow2(len(silos)))
    batch_loss = federated._make_batch_loss(_loss, True, 0.0)
    host = federated._run_host(
        batch_loss, _params(), padded, opt=adamw(1e-2), rounds=KW["rounds"],
        local_epochs=KW["local_epochs"], aggregator="fedavg", seed=0,
        eval_fn=None, per_example=True, reset_opt=True)
    np.testing.assert_allclose(_flat(res), _flat(host), rtol=1e-4, atol=1e-5)


def test_warm_plan_cache_hit_compiles_nothing():
    """The direct claim behind the cache tier: a warm hit builds ZERO new
    executables (CompileCounter patches the backend compiler, so this can't
    be fooled by fast-but-recompiling paths the old timing checks missed)."""
    from repro.analysis import CompileCounter

    cache = PlanCache()
    tenant = _silos(3, 20, seed=0)
    _run(tenant, cache)                          # cold: builds + warms jits
    with CompileCounter() as cc:
        warm = _run(_silos(3, 20, seed=1), cache)    # same shapes, new data
    assert warm.cache_stats["hit"] is True
    assert cc.count == 0, f"warm cache hit compiled {cc.count} modules"


# ---------------------------------------------------------------------------
# counters, bucket sharing, aliasing, eviction
# ---------------------------------------------------------------------------

def test_counters_and_bucket_sharing():
    cache = PlanCache()
    r1 = _run(_silos(3, 20, seed=0), cache)      # d=3 -> silo bucket 4
    r2 = _run(_silos(4, 18, seed=1), cache)      # d=4 -> same bucket, hits
    assert cache.stats() == {"hits": 1, "misses": 1, "evictions": 0,
                             "plans": 1}
    assert r1.cache_stats["hit"] is False and r2.cache_stats["hit"] is True


def test_distinct_configs_never_alias():
    cache = PlanCache()
    silos = _silos(3, 20, seed=0)
    base = _run(silos, cache)
    prox = _run(silos, cache, aggregator="fedprox", fedprox_mu=0.1)
    carry = _run(silos, cache, reset_opt_per_round=False)
    s = cache.stats()
    assert s["misses"] == 3 and s["hits"] == 0 and s["plans"] == 3
    again = _run(silos, cache)                   # base config now hits
    assert again.cache_stats["hit"] is True
    np.testing.assert_allclose(_flat(again), _flat(base), rtol=1e-6)
    # the three configs genuinely train differently — aliasing would
    # silently collapse them onto one executable
    assert not np.allclose(_flat(base), _flat(prox))
    assert not np.allclose(_flat(base), _flat(carry))


def test_lru_eviction():
    cache = PlanCache(max_plans=1)
    _run(_silos(2, 10, seed=0), cache)           # bucket (2 silos, 2 batches)
    _run(_silos(3, 20, seed=1), cache)           # bucket (4, 4) -> evicts
    assert cache.stats()["evictions"] == 1 and len(cache) == 1
    r = _run(_silos(2, 10, seed=0), cache)       # evicted -> rebuilds
    assert r.cache_stats["hit"] is False


def test_mesh_enters_plan_key_and_never_aliases():
    """A sharded and an unsharded plan over the same bucketed layout must
    be distinct cache entries (their executables differ: shard_map + psums
    vs plain vmap), while two runs on the SAME mesh share one."""
    from repro.launch.mesh import make_host_mesh

    cache = PlanCache()
    silos = _silos(3, 20, seed=0)
    base = _run(silos, cache)
    mesh = make_host_mesh(model=1)
    sharded = _run(silos, cache, mesh=mesh)
    assert sharded.cache_stats["hit"] is False        # no alias
    again = _run(_silos(3, 22, seed=1), cache, mesh=mesh)
    assert again.cache_stats["hit"] is True           # same mesh -> hit
    s = cache.stats()
    assert s["plans"] == 2 and s["misses"] == 2 and s["hits"] == 1
    np.testing.assert_allclose(_flat(base), _flat(sharded),
                               rtol=1e-5, atol=1e-6)


def test_chunk_mode_plan_is_rounds_agnostic():
    """With eval_fn the cached plan is the streamed chunk step, which never
    bakes `rounds` into the executable — a rounds=3 and a rounds=5 run
    share ONE plan (the win that makes rounds≫10 configs cacheable)."""
    cache = PlanCache()
    silos = _silos(3, 20, seed=0)
    ev = lambda p: {"w0": float(np.asarray(
        jax.tree.leaves(p)[0]).ravel()[0])}
    r3 = _run(silos, cache, rounds=3, eval_fn=ev)
    r5 = _run(silos, cache, rounds=5, eval_fn=ev)
    assert r3.cache_stats["hit"] is False
    assert r5.cache_stats["hit"] is True
    assert cache.stats()["plans"] == 1
    assert len(r3.history) == 3 and len(r5.history) == 5
    # the shared executable still trains: prefixes agree round-for-round
    for a, b in zip(r3.history, r5.history):
        assert a["loss"] == pytest.approx(b["loss"], rel=1e-6)
        assert a["w0"] == pytest.approx(b["w0"], rel=1e-6)


def test_cache_requires_scan_engine():
    with pytest.raises(ValueError):
        _run(_silos(2, 10), PlanCache(), engine="host")


# ---------------------------------------------------------------------------
# sample counts stay integral (float32 counts corrupt above 2^24)
# ---------------------------------------------------------------------------

def test_sample_counts_stay_integral():
    padded = pad_silo_data(_silos(3, 20), 8, min_silos=4)
    assert np.issubdtype(padded.sizes.dtype, np.integer)
    assert padded.sizes.tolist() == [20, 23, 26, 0]   # bucket silo: size 0
    big = np.array([2 ** 24 + 1, 2 ** 24], np.int64)
    # the hazard the integral dtype guards against:
    assert np.float32(big[0]) == np.float32(big[1])
    # float64 normalization keeps the order; the cast happens only after
    w64 = np.asarray(big, np.float64)
    w64 /= w64.sum()
    assert w64[0] > w64[1]
    w = federated._norm_weights(big)
    assert w.dtype == np.float32
    assert abs(float(w.sum()) - 1.0) < 1e-6
    np.testing.assert_allclose(federated._norm_weights(np.array([1, 3])),
                               [0.25, 0.75], rtol=0)


# ---------------------------------------------------------------------------
# the FedDCL.fit() facade rides the same cache
# ---------------------------------------------------------------------------

def _groups(n_ij, seed):
    r = np.random.default_rng(seed)
    w = r.standard_normal((M, 1))
    Xs = [[r.standard_normal((n_ij, M)) for _ in range(2)] for _ in range(2)]
    Ys = [[x @ w + 0.01 * r.standard_normal((n_ij, 1)) for x in g]
          for g in Xs]
    return Xs, Ys


def test_api_fit_reuses_executables_across_tenants():
    from repro.api import FedDCL
    from repro.core.federated import default_plan_cache

    default_plan_cache().clear()
    m1 = FedDCL(m_tilde=4, anchor_r=64, rounds=2, local_epochs=1, seed=0)
    _, res1 = m1.fit(*_groups(20, 0))
    assert res1.cache_stats["hit"] is False
    # a fresh estimator on a new same-bucket tenant hits the shared cache
    m2 = FedDCL(m_tilde=4, anchor_r=64, rounds=2, local_epochs=1, seed=1)
    Xs2, Ys2 = _groups(24, 1)
    setup2, res2 = m2.fit(Xs2, Ys2)
    assert res2.cache_stats["hit"] is True
    assert default_plan_cache().stats()["misses"] == 1
    yhat = m2.predict(Xs2[0][0])
    assert yhat.shape == (24, 1) and np.all(np.isfinite(yhat))
    assert np.isfinite(m2.score(Xs2[0][0], Ys2[0][0]))
    assert setup2.collab_X[0].shape[1] == 4


def test_api_fit_warm_path_compiles_nothing():
    """End-to-end recompile sentinel: a second same-shape tenant through
    FedDCL.fit() must not build a single executable — the FL plan comes
    from the shared PlanCache and every collab-phase jit re-hits its trace
    cache (tenants must share shapes: a different n would legitimately
    recompile the collab projections)."""
    from repro.analysis import CompileCounter
    from repro.api import FedDCL
    from repro.core.federated import default_plan_cache

    default_plan_cache().clear()
    m1 = FedDCL(m_tilde=4, anchor_r=64, rounds=2, local_epochs=1, seed=0)
    m1.fit(*_groups(20, 0))
    m2 = FedDCL(m_tilde=4, anchor_r=64, rounds=2, local_epochs=1, seed=1)
    with CompileCounter() as cc:
        _, res2 = m2.fit(*_groups(20, 1))
    assert res2.cache_stats["hit"] is True
    assert cc.count == 0, f"warm fit() compiled {cc.count} modules"


# ---------------------------------------------------------------------------
# persistent XLA compilation cache wiring
# ---------------------------------------------------------------------------

def test_persistent_compilation_cache_populates(tmp_path):
    from repro import api

    prev = api._COMPILE_CACHE_ENABLED
    d = str(tmp_path / "xla")
    try:
        assert api.enable_persistent_compilation_cache(d) == d
        assert api.enable_persistent_compilation_cache(d) == d   # idempotent
        f = jax.jit(lambda x: jnp.tanh(x * 2.0) @ x.T)
        f(jnp.arange(32.0).reshape(4, 8)).block_until_ready()
        assert os.listdir(d), "compilation cache dir stayed empty"
    finally:
        api._COMPILE_CACHE_ENABLED = prev
        try:
            jax.config.update("jax_compilation_cache_dir", prev)
        except Exception:
            pass
