"""Federated tier: host simulation semantics + mesh-level collective
structure (the paper's 'no iterative cross-silo traffic' made checkable)."""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.federated import (fedavg_average, fedavg_sync, run_federated,
                                  silo_replicate)
from repro.models import mlp
from repro.optim import adamw, sgd


def _toy_data(n=64, m=4, silos=2, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, 1))
    X = rng.standard_normal((n, m))
    Y = X @ w + 0.01 * rng.standard_normal((n, 1))
    per = n // silos
    return [(X[i * per:(i + 1) * per], Y[i * per:(i + 1) * per])
            for i in range(silos)], (X, Y)


def test_fedavg_average_weighted():
    p1 = {"w": jnp.ones((2, 2))}
    p2 = {"w": jnp.zeros((2, 2))}
    avg = fedavg_average([p1, p2], [3, 1])
    np.testing.assert_allclose(np.asarray(avg["w"]), 0.75)


def test_fedavg_learns_linear_regression():
    silo_data, (X, Y) = _toy_data()
    params = mlp.init_mlp_params(jax.random.PRNGKey(0), 4, (8,), 1)
    loss = lambda p, x, y: mlp.mlp_loss(p, x, y, "regression")
    res = run_federated(loss, params, silo_data, opt=adamw(1e-2), rounds=15,
                        local_epochs=2, batch_size=16)
    final = float(loss(res.params, jnp.asarray(X), jnp.asarray(Y)))
    assert final < 0.1, final


def test_fedprox_stays_closer_to_global():
    silo_data, _ = _toy_data(seed=3)
    params = mlp.init_mlp_params(jax.random.PRNGKey(0), 4, (8,), 1)
    loss = lambda p, x, y: mlp.mlp_loss(p, x, y, "regression")
    res_avg = run_federated(loss, params, silo_data, opt=adamw(1e-2),
                            rounds=3, local_epochs=2)
    res_prox = run_federated(loss, params, silo_data, opt=adamw(1e-2),
                             rounds=3, local_epochs=2, aggregator="fedprox",
                             fedprox_mu=10.0)
    # strong proximal term keeps params nearer the start
    d_avg = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(
        jax.tree_util.tree_leaves(res_avg.params),
        jax.tree_util.tree_leaves(params)))
    d_prox = sum(float(jnp.sum((a - b) ** 2)) for a, b in zip(
        jax.tree_util.tree_leaves(res_prox.params),
        jax.tree_util.tree_leaves(params)))
    assert d_prox < d_avg


def test_fedsgd_runs():
    silo_data, (X, Y) = _toy_data()
    params = mlp.init_mlp_params(jax.random.PRNGKey(0), 4, (8,), 1)
    loss = lambda p, x, y: mlp.mlp_loss(p, x, y, "regression")
    res = run_federated(loss, params, silo_data, opt=sgd(1e-1), rounds=50,
                        aggregator="fedsgd", local_epochs=1)
    final = float(loss(res.params, jnp.asarray(X), jnp.asarray(Y)))
    assert np.isfinite(final)


def test_silo_replicate_and_sync_roundtrip():
    params = {"w": jnp.arange(6.0).reshape(2, 3)}
    sp = silo_replicate(params, 4)
    assert sp["w"].shape == (4, 2, 3)
    # perturb silos differently, sync = mean
    sp = {"w": sp["w"] + jnp.arange(4.0)[:, None, None]}
    synced = fedavg_sync(sp)
    np.testing.assert_allclose(np.asarray(synced["w"][0]),
                               np.asarray(params["w"]) + 1.5)
    np.testing.assert_allclose(np.asarray(synced["w"][0]),
                               np.asarray(synced["w"][3]))


def test_weighted_sync():
    sp = {"w": jnp.stack([jnp.zeros((2,)), jnp.ones((2,))])}
    synced = fedavg_sync(sp, weights=jnp.asarray([1.0, 3.0]))
    np.testing.assert_allclose(np.asarray(synced["w"][0]), 0.75)


MESH_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    from repro.configs import REDUCED
    from repro.configs.base import TrainConfig, InputShape, FederatedConfig
    from repro.launch.specs import make_plan
    from repro.launch.roofline import iter_collectives
    cfg = REDUCED["llama3.2-1b"]
    try:
        # axis_types / AxisType only exist on jax >= 0.5; the pinned CI jax
        # (0.4.37) takes the portable spelling below
        mesh = jax.make_mesh((4, 2), ("data", "model"),
                             axis_types=(jax.sharding.AxisType.Auto,)*2)
    except (AttributeError, TypeError):
        mesh = jax.make_mesh((4, 2), ("data", "model"))
    shape = InputShape("t", seq_len=64, global_batch=8, kind="train")
    tc = TrainConfig(model=cfg, shape=shape, remat=False,
                     param_dtype="float32", compute_dtype="float32",
                     federated=FederatedConfig(num_silos=4, local_steps=4))

    def cross_silo(plan_mode):
        plan = make_plan(cfg, shape, mesh, mode=plan_mode, tc=tc)
        with mesh:
            c = jax.jit(plan.step_fn, in_shardings=plan.in_shardings,
                        out_shardings=plan.out_shardings
                        ).lower(*plan.args).compile()
        bad = 0
        # silo = data row; with (4,2) mesh, device // 2 = silo index
        for op, nbytes, groups in iter_collectives(c.as_text(), 8):
            for grp in groups:
                if len({d // 2 for d in grp}) > 1:
                    bad += 1
        return bad

    print("CLEAN" if cross_silo("feddcl") == 0 else "BAD")
    print("SYNC_CROSSES" if cross_silo("feddcl_sync") > 0 else "SYNC_LOCAL")
""")


@pytest.mark.slow
def test_no_cross_silo_collectives_in_local_step():
    """The lowered federated LOCAL step must contain no collective whose
    replica group spans silo boundaries; the SYNC step must contain one."""
    r = subprocess.run([sys.executable, "-c", MESH_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    assert "CLEAN" in r.stdout, r.stdout
    assert "SYNC_CROSSES" in r.stdout, r.stdout
