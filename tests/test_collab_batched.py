"""Batched device-resident collaboration engine: agreement of the batched
Gram / top-k / least-squares primitives with their NumPy oracles, and
host-vs-device agreement of the full protocol."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import collab
from repro.core.protocol import run_protocol
from repro.data.partition import split_iid
from repro.data.tabular import make_dataset, train_test_split
from repro.kernels.gram import ops as gram_ops, ref as gram_ref


# --------------------------------------------------------------------------
# gram_batched vs NumPy oracle
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,r,m", [(1, 64, 16), (4, 300, 48), (7, 129, 65),
                                   (16, 512, 32)])
def test_gram_batched_ref_matches_numpy(B, r, m):
    rng = np.random.default_rng(0)
    a = rng.standard_normal((B, r, m)).astype(np.float32)
    g = np.asarray(gram_ops.gram_batched(jnp.asarray(a), backend="ref"))
    g_np = np.einsum("brm,brn->bmn", a, a)
    np.testing.assert_allclose(g, g_np, atol=5e-3 * r ** 0.5, rtol=5e-3)


@pytest.mark.parametrize("B,r,m", [(2, 100, 32), (3, 300, 48), (5, 513, 129)])
def test_gram_batched_pallas_interpret_matches_ref(B, r, m):
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.standard_normal((B, r, m)), jnp.float32)
    g_int = np.asarray(gram_ops.gram_batched(a, backend="interpret"))
    g_ref = np.asarray(gram_ref.gram_batched_reference(a))
    np.testing.assert_allclose(g_int, g_ref, atol=5e-3 * r ** 0.5, rtol=5e-3)


def test_gram_batched_matches_per_slice_gram():
    """The batched launch is exactly the stack of single-matrix launches."""
    rng = np.random.default_rng(2)
    a = jnp.asarray(rng.standard_normal((6, 200, 40)), jnp.float32)
    g_b = np.asarray(gram_ops.gram_batched(a, backend="ref"))
    for i in range(6):
        g_i = np.asarray(gram_ops.gram(a[i], backend="ref"))
        np.testing.assert_allclose(g_b[i], g_i, atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# batched top-k recovery
# --------------------------------------------------------------------------

def test_gram_eigh_topk_batched_matches_svd():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((5, 400, 30)).astype(np.float32)
    U, s, V = gram_ops.gram_eigh_topk_batched(jnp.asarray(a), 8, backend="ref")
    U, s, V = np.asarray(U), np.asarray(s), np.asarray(V)
    for b in range(5):
        s_ref = np.linalg.svd(a[b], compute_uv=False)[:8]
        np.testing.assert_allclose(s[b], s_ref, rtol=1e-3)
        np.testing.assert_allclose(U[b].T @ U[b], np.eye(8), atol=1e-2)
        np.testing.assert_allclose(a[b] @ V[b], U[b] * s[b][None, :],
                                   atol=1e-2)


def test_gram_eigh_topk_batched_zero_padded_columns():
    """Zero-padded columns must stay in the null space: top-k pairs of the
    padded stack match the unpadded per-matrix SVDs."""
    rng = np.random.default_rng(4)
    widths = [10, 6, 14]
    mats = [rng.standard_normal((200, w)).astype(np.float32) for w in widths]
    padded, _ = collab.pad_ragged(mats)
    U, s, V = gram_ops.gram_eigh_topk_batched(jnp.asarray(padded), 5,
                                              backend="ref")
    for b, (A, w) in enumerate(zip(mats, widths)):
        s_ref = np.linalg.svd(A, compute_uv=False)[:5]
        np.testing.assert_allclose(np.asarray(s)[b], s_ref, rtol=1e-3)
        # V mass is confined to the real columns
        if w < padded.shape[2]:
            assert np.abs(np.asarray(V)[b, w:, :]).max() < 1e-4


# --------------------------------------------------------------------------
# solve_G_batched vs np.linalg.lstsq over ragged widths
# --------------------------------------------------------------------------

@pytest.mark.parametrize("widths", [[6], [6, 3, 5, 2], [8, 8, 8],
                                    [1, 12, 4, 7, 2, 9]])
def test_solve_G_batched_matches_lstsq_ragged(widths):
    rng = np.random.default_rng(5)
    r, m_hat = 250, 4
    Z = rng.standard_normal((r, m_hat)).astype(np.float32)
    mats = [rng.standard_normal((r, w)).astype(np.float32) for w in widths]
    padded, mask = collab.pad_ragged(mats)
    G = np.asarray(gram_ops.solve_G_batched(jnp.asarray(padded),
                                            jnp.asarray(Z),
                                            jnp.asarray(mask)))
    for b, (A, w) in enumerate(zip(mats, widths)):
        G_ref, *_ = np.linalg.lstsq(A, Z, rcond=None)
        np.testing.assert_allclose(G[b, :w], G_ref, atol=2e-3, rtol=2e-3)
        assert np.all(G[b, w:] == 0.0), "padded rows must be exactly zero"


def test_solve_G_batched_per_batch_targets():
    rng = np.random.default_rng(6)
    A = rng.standard_normal((3, 100, 8)).astype(np.float32)
    Z = rng.standard_normal((3, 100, 4)).astype(np.float32)
    G = np.asarray(gram_ops.solve_G_batched(jnp.asarray(A), jnp.asarray(Z)))
    for b in range(3):
        G_ref, *_ = np.linalg.lstsq(A[b], Z[b], rcond=None)
        np.testing.assert_allclose(G[b], G_ref, atol=2e-3, rtol=2e-3)


def test_solve_G_all_device_matches_host():
    rng = np.random.default_rng(7)
    anchors = [rng.standard_normal((300, w)) for w in (5, 9, 3)]
    Z = rng.standard_normal((300, 4))
    G_host = collab.solve_G_all(anchors, Z, backend="host")
    G_dev = collab.solve_G_all(anchors, Z, backend="device")
    for gh, gd in zip(G_host, G_dev):
        assert gh.shape == gd.shape
        np.testing.assert_allclose(gd, gh, atol=2e-3, rtol=2e-3)


# --------------------------------------------------------------------------
# apply_G_all: batched per-user X̂ = X̃ G (step 12)
# --------------------------------------------------------------------------

def test_apply_G_all_device_matches_host_ragged_both_axes():
    """Users ragged in rows (n_j), G-input cols (m̃_j) AND G-output cols
    (m̂_j): the device path's single padded matmul must slice back to each
    user's exact host-product shape and values."""
    rng = np.random.default_rng(4)
    shapes = [(30, 6, 3), (17, 8, 5), (44, 4, 4)]       # (n_j, m̃_j, m̂_j)
    Xs = [rng.standard_normal((n, mt)) for n, mt, _ in shapes]
    Gs = [rng.standard_normal((mt, mh)) for _, mt, mh in shapes]
    host = collab.apply_G_all(Xs, Gs, backend="host")
    dev = collab.apply_G_all(Xs, Gs, backend="device")
    for h, dv, (n, mt, mh) in zip(host, dev, shapes):
        assert h.shape == dv.shape == (n, mh)
        np.testing.assert_allclose(dv, h, atol=1e-4, rtol=1e-4)


# --------------------------------------------------------------------------
# full protocol: host vs device
# --------------------------------------------------------------------------

@pytest.fixture(scope="module")
def partitions():
    ds = make_dataset("battery_small", n=900, seed=0)
    (Xtr, Ytr), _ = train_test_split(ds, 400, 400, seed=0)
    return Xtr, Ytr


@pytest.mark.parametrize("d,c", [(2, [2, 2]), (2, [1, 3]), (3, [1, 1, 1])])
def test_run_protocol_device_matches_host(partitions, d, c):
    Xtr, Ytr = partitions
    Xs, Ys = split_iid(Xtr, Ytr, d=d, c=c, n_ij=60, seed=0)
    host = run_protocol(Xs, Ys, m_tilde=4, anchor_r=600, seed=0,
                        svd_backend="host")
    dev = run_protocol(Xs, Ys, m_tilde=4, anchor_r=600, seed=0,
                       svd_backend="device")
    for Xh, Xd in zip(host.collab_X, dev.collab_X):
        rel = np.linalg.norm(Xh - Xd) / np.linalg.norm(Xh)
        assert rel <= 1e-3, rel
    rel_Z = np.linalg.norm(host.Z - dev.Z) / np.linalg.norm(host.Z)
    assert rel_Z <= 1e-3, rel_Z


def test_device_path_makes_zero_lstsq_calls(partitions, monkeypatch):
    """The acceptance criterion: no per-user Python-loop lstsq on device."""
    Xtr, Ytr = partitions
    Xs, Ys = split_iid(Xtr, Ytr, d=2, c=[2, 2], n_ij=60, seed=0)
    calls = []
    real = np.linalg.lstsq

    def counting_lstsq(*args, **kwargs):
        calls.append(1)
        return real(*args, **kwargs)

    monkeypatch.setattr(np.linalg, "lstsq", counting_lstsq)
    run_protocol(Xs, Ys, m_tilde=4, anchor_r=600, seed=0,
                 svd_backend="device")
    assert calls == [], f"device path made {len(calls)} lstsq calls"
    run_protocol(Xs, Ys, m_tilde=4, anchor_r=600, seed=0, svd_backend="host")
    assert len(calls) == 4, "host path should lstsq once per user"


def test_topk_svd_many_ragged_widths_match_host_clamp():
    """Per-matrix k clamp: a narrow group must not truncate wider groups'
    bases on the device backend (regression: global-min clamp)."""
    rng = np.random.default_rng(8)
    groups = [[rng.standard_normal((200, 8))],
              [rng.standard_normal((200, 16)), rng.standard_normal((200, 16))]]
    for m_hat in (4, 16):
        host = collab.intra_group_bases(groups, m_hat, seeds=[0, 1],
                                        backend="host")
        dev = collab.intra_group_bases(groups, m_hat, seeds=[0, 1],
                                       backend="device")
        assert [b.B.shape for b in host] == [b.B.shape for b in dev]
        for bh, bd in zip(host, dev):
            rel = np.linalg.norm(bh.B - bd.B) / np.linalg.norm(bh.B)
            assert rel <= 1e-3, rel


def test_solve_G_batched_ridge_bounds_rank_deficient():
    """QR needs full-column-rank anchors; ridge > 0 is the documented escape
    hatch that keeps degenerate (collinear-column) solves bounded."""
    rng = np.random.default_rng(9)
    A = rng.standard_normal((200, 6)).astype(np.float32)
    A[:, 3] = A[:, 2]                       # exactly collinear pair
    Z = rng.standard_normal((200, 4)).astype(np.float32)
    G = np.asarray(gram_ops.solve_G_batched(jnp.asarray(A[None]),
                                            jnp.asarray(Z), ridge=1e-3))[0]
    assert np.all(np.isfinite(G))
    assert np.abs(G).max() < 1e3
    # residual still ~ least-squares quality
    res = np.linalg.norm(A @ G - Z)
    G_ls, *_ = np.linalg.lstsq(A, Z, rcond=None)
    res_ls = np.linalg.norm(A @ G_ls - Z)
    assert res < res_ls * 1.01
    # and ridge leaves well-conditioned solves essentially unchanged
    B = rng.standard_normal((200, 6)).astype(np.float32)
    G_r = np.asarray(gram_ops.solve_G_batched(jnp.asarray(B[None]),
                                              jnp.asarray(Z), ridge=1e-3))[0]
    G_0, *_ = np.linalg.lstsq(B, Z, rcond=None)
    np.testing.assert_allclose(G_r, G_0, atol=5e-3, rtol=5e-3)


def test_get_backend_names():
    assert collab.get_backend("host").name == "host"
    assert collab.get_backend("device").name == "device"
    assert collab.get_backend("tpu").name == "device"   # legacy alias
    with pytest.raises(ValueError):
        collab.get_backend("gpu-madeup")
