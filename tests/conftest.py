"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests and benchmarks
must see the real single device; only launch/dryrun.py forces 512 host
devices (and mesh-lowering tests spawn subprocesses with their own env)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
