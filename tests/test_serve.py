"""Serve-path slot-table invariants (fast; tiny 1-layer config).

Pin the slot-drift fixes: idle slots must not advance their cache
position, a released slot must reset pos/cur_tok before the next tenant,
and an empty prompt must serve instead of crashing prefill.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REDUCED
from repro.launch.serve import BatchedServer, Request
from repro.models import backbone as bb

TINY = dataclasses.replace(REDUCED["llama3.2-1b"], num_layers=1, d_model=64,
                           num_heads=2, num_kv_heads=2, head_dim=32,
                           d_ff=128, vocab_size=64)


@pytest.fixture(scope="module")
def tiny_params():
    return bb.init_params(TINY, jax.random.PRNGKey(0), jnp.float32)


def test_idle_slots_hold_position(tiny_params):
    server = BatchedServer(TINY, tiny_params, slots=3, cache_len=32)
    outs = server.serve([Request(rid=0, prompt=np.array([1, 2, 3]),
                                 max_new=6)])
    assert len(outs[0]) == 6
    pos = np.asarray(server.pos)
    # slots 1 and 2 never admitted a request: the always-advancing pos bug
    # marched them 1 step per decode regardless
    assert pos[1] == 0 and pos[2] == 0


def test_released_slot_resets(tiny_params):
    server = BatchedServer(TINY, tiny_params, slots=2, cache_len=32)
    outs = server.serve([Request(rid=0, prompt=np.array([4, 5]), max_new=3)])
    assert len(outs[0]) == 3
    assert int(server.pos[0]) == 0             # released -> pos reset
    assert int(server.cur_tok[0, 0]) == 0      # ...and no stale token decoded
    assert server.active[0] is None


def test_empty_prompt_serves(tiny_params):
    server = BatchedServer(TINY, tiny_params, slots=2, cache_len=32)
    outs = server.serve([Request(rid=0, prompt=np.array([], np.int32),
                                 max_new=3),
                         Request(rid=1, prompt=np.array([5, 6, 7]),
                                 max_new=3)])
    assert len(outs[0]) == 3 and len(outs[1]) == 3
    assert all(0 <= t < TINY.vocab_size for t in outs[0])


def test_slot_reuse_across_queue(tiny_params):
    """More requests than slots: released slots serve the queue tail."""
    server = BatchedServer(TINY, tiny_params, slots=2, cache_len=32)
    reqs = [Request(rid=i, prompt=np.array([i + 1, i + 2]), max_new=4)
            for i in range(5)]
    outs = server.serve(reqs)
    assert sorted(outs) == [0, 1, 2, 3, 4]
    assert all(len(v) == 4 for v in outs.values())


def test_prefill_leaves_live_slots_untouched(tiny_params):
    """Admission prefill is a B=1 slice of the new slot's cache: the state,
    position, and pending token of every other slot must be bit-identical
    before and after (the old full-batch prefill re-decoded all slots P
    times per admitted prompt)."""
    server = BatchedServer(TINY, tiny_params, slots=3, cache_len=32)
    req_a = Request(rid=0, prompt=np.array([1, 2, 3]), max_new=8)
    server.active[0] = req_a
    server._prefill_slot(0, req_a)
    before = jax.tree.map(lambda a: np.asarray(a[:, 0:1]), server.state)
    pos0, tok0 = int(server.pos[0]), int(server.cur_tok[0, 0])
    req_b = Request(rid=1, prompt=np.array([7, 8, 9, 10]), max_new=8)
    server.active[1] = req_b
    server._prefill_slot(1, req_b)
    after = jax.tree.map(lambda a: np.asarray(a[:, 0:1]), server.state)
    jax.tree.map(np.testing.assert_array_equal, before, after)
    assert int(server.pos[0]) == pos0
    assert int(server.cur_tok[0, 0]) == tok0


def test_live_output_invariant_to_admission(tiny_params):
    """A request's greedy output must not change because other requests
    were admitted into neighboring slots mid-flight."""
    solo = BatchedServer(TINY, tiny_params, slots=2, cache_len=32)
    ref = solo.serve([Request(rid=0, prompt=np.array([3, 1, 4]),
                              max_new=6)])[0]
    busy = BatchedServer(TINY, tiny_params, slots=2, cache_len=32)
    reqs = [Request(rid=0, prompt=np.array([3, 1, 4]), max_new=6)] + [
        Request(rid=i, prompt=np.array([i, i + 1]), max_new=2)
        for i in range(1, 4)]
    outs = busy.serve(reqs)
    assert outs[0] == ref


def test_status_reports_partial_service(tiny_params):
    """max_steps cuts serving short: the result must say WHICH requests
    finished. Before ServeResult.status, a half-decoded request and a
    finished one were indistinguishable in the returned mapping."""
    server = BatchedServer(TINY, tiny_params, slots=1, cache_len=32)
    reqs = [Request(rid=0, prompt=np.array([1, 2]), max_new=3),
            Request(rid=1, prompt=np.array([3, 4]), max_new=30),
            Request(rid=2, prompt=np.array([5, 6]), max_new=3)]
    # slots=1 serves FIFO: rid 0 finishes, rid 1 is cut mid-decode at
    # max_steps, rid 2 never reaches the slot
    outs = server.serve(reqs, max_steps=6)
    assert outs.status[0] == "done" and len(outs[0]) == 3
    assert outs.status[1] == "truncated" and 0 < len(outs[1]) < 30
    assert outs.status[2] == "pending" and outs[2] == []


def test_status_all_done_when_drained(tiny_params):
    server = BatchedServer(TINY, tiny_params, slots=2, cache_len=32)
    reqs = [Request(rid=i, prompt=np.array([i + 1]), max_new=3)
            for i in range(4)]
    outs = server.serve(reqs)
    assert all(s == "done" for s in outs.status.values())
    assert sorted(outs.status) == [0, 1, 2, 3]


def test_temperature_sampling_reproducible(tiny_params):
    """temperature>0 sampling keys on (rid, tokens emitted) — the same
    request produces the same stream whether it runs alone in 1 slot or
    shares a 3-slot table with a batch-mate (the old split-per-sample key
    tied every draw to global serve history)."""
    def run(slots, extra):
        server = BatchedServer(TINY, tiny_params, slots=slots, cache_len=32,
                               temperature=1.0, seed=7)
        reqs = [Request(rid=0, prompt=np.array([2, 3]), max_new=5)]
        if extra:
            reqs.append(Request(rid=1, prompt=np.array([9, 8, 7]),
                                max_new=5))
        return server.serve(reqs)[0]

    assert run(1, False) == run(3, True)
