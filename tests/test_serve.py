"""Serve-path slot-table invariants (fast; tiny 1-layer config).

Pin the slot-drift fixes: idle slots must not advance their cache
position, a released slot must reset pos/cur_tok before the next tenant,
and an empty prompt must serve instead of crashing prefill.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REDUCED
from repro.launch.serve import BatchedServer, Request
from repro.models import backbone as bb

TINY = dataclasses.replace(REDUCED["llama3.2-1b"], num_layers=1, d_model=64,
                           num_heads=2, num_kv_heads=2, head_dim=32,
                           d_ff=128, vocab_size=64)


@pytest.fixture(scope="module")
def tiny_params():
    return bb.init_params(TINY, jax.random.PRNGKey(0), jnp.float32)


def test_idle_slots_hold_position(tiny_params):
    server = BatchedServer(TINY, tiny_params, slots=3, cache_len=32)
    outs = server.serve([Request(rid=0, prompt=np.array([1, 2, 3]),
                                 max_new=6)])
    assert len(outs[0]) == 6
    pos = np.asarray(server.pos)
    # slots 1 and 2 never admitted a request: the always-advancing pos bug
    # marched them 1 step per decode regardless
    assert pos[1] == 0 and pos[2] == 0


def test_released_slot_resets(tiny_params):
    server = BatchedServer(TINY, tiny_params, slots=2, cache_len=32)
    outs = server.serve([Request(rid=0, prompt=np.array([4, 5]), max_new=3)])
    assert len(outs[0]) == 3
    assert int(server.pos[0]) == 0             # released -> pos reset
    assert int(server.cur_tok[0, 0]) == 0      # ...and no stale token decoded
    assert server.active[0] is None


def test_empty_prompt_serves(tiny_params):
    server = BatchedServer(TINY, tiny_params, slots=2, cache_len=32)
    outs = server.serve([Request(rid=0, prompt=np.array([], np.int32),
                                 max_new=3),
                         Request(rid=1, prompt=np.array([5, 6, 7]),
                                 max_new=3)])
    assert len(outs[0]) == 3 and len(outs[1]) == 3
    assert all(0 <= t < TINY.vocab_size for t in outs[0])


def test_slot_reuse_across_queue(tiny_params):
    """More requests than slots: released slots serve the queue tail."""
    server = BatchedServer(TINY, tiny_params, slots=2, cache_len=32)
    reqs = [Request(rid=i, prompt=np.array([i + 1, i + 2]), max_new=4)
            for i in range(5)]
    outs = server.serve(reqs)
    assert sorted(outs) == [0, 1, 2, 3, 4]
    assert all(len(v) == 4 for v in outs.values())
