"""serve_collab invariants: bucketed dispatch correctness, statuses,
executable sharing, the zero-recompile warm path, no baked tenant data in
the artifact, and live onboarding (DESIGN.md §10)."""
import jax
import numpy as np
import pytest

from repro.analysis.hlo_audit import CompileCounter, assert_no_baked_data
from repro.core import protocol
from repro.core.federated import PlanCache
from repro.models import mlp
from repro.serve_collab import CollabRequest, ServeCollab

M_RAW = 7


@pytest.fixture(scope="module")
def fitted():
    rng = np.random.default_rng(3)
    counts = [2, 3, 4]
    Xs = [[rng.standard_normal((35, M_RAW)) for _ in range(c)]
          for c in counts]
    Ys = [[rng.standard_normal((35, 1)) for _ in range(c)] for c in counts]
    setup = protocol.run_protocol(Xs, Ys, m_tilde=4, anchor_r=120, seed=0,
                                  onboard=True)
    params = mlp.init_mlp_params(jax.random.PRNGKey(0), setup.m_hat, (16,), 1)
    return setup, params


def _direct(setup, params, i, j, x):
    """Reference: the finalized per-user model, no batching/padding."""
    h = np.asarray(setup.user_transform(i, j)(x), np.float32)
    return np.asarray(mlp.mlp_forward(params, h))


def test_mixed_tenant_batches_match_direct_path(fitted):
    setup, params = fitted
    srv = ServeCollab.from_setup(setup, params, max_batch=16)
    rng = np.random.default_rng(1)
    checks = []
    for _ in range(15):
        g = int(rng.integers(0, setup.num_groups))
        u = int(rng.integers(0, setup.num_users(g)))
        x = rng.standard_normal((int(rng.integers(1, 40)), M_RAW))
        checks.append((srv.submit(x, g, u), g, u, x))
    out = srv.serve()
    assert set(out.status.values()) == {"done"}
    for req, g, u, x in checks:
        ref = _direct(setup, params, g, u, x)
        np.testing.assert_allclose(out[req.rid], ref, rtol=0, atol=2e-5)


def test_oversize_request_chunks_across_steps(fitted):
    setup, params = fitted
    srv = ServeCollab.from_setup(setup, params, max_batch=8)
    rng = np.random.default_rng(2)
    x = rng.standard_normal((30, M_RAW))           # 30 rows through batch 8
    req = srv.submit(x, 1, 0)
    out = srv.serve()
    assert out.status[req.rid] == "done"
    assert out[req.rid].shape[0] == 30
    np.testing.assert_allclose(out[req.rid], _direct(setup, params, 1, 0, x),
                               rtol=0, atol=2e-5)
    assert srv.steps >= 4                          # genuinely chunked


def test_status_distinguishes_cutoff_requests(fitted):
    setup, params = fitted
    srv = ServeCollab.from_setup(setup, params, max_batch=4)
    rng = np.random.default_rng(3)
    r0 = srv.submit(rng.standard_normal((3, M_RAW)), 0, 0)
    r1 = srv.submit(rng.standard_normal((20, M_RAW)), 0, 1)
    r2 = srv.submit(rng.standard_normal((5, M_RAW)), 1, 0)
    out = srv.serve(max_steps=2)
    assert out.status[r0.rid] == "done"
    assert out.status[r1.rid] == "truncated"
    assert 0 < out[r1.rid].shape[0] < 20           # partial rows, flagged
    assert out.status[r2.rid] == "pending" and out[r2.rid].size == 0
    # draining the queue finishes the rest
    out2 = srv.serve()
    assert out2.status[r1.rid] == "done" and out2.status[r2.rid] == "done"


def test_same_shape_groups_share_one_executable(fitted):
    """The plan key carries only SHAPES: groups with equal (T_pad, B_pad)
    hit one plan; tenant identity lives in runtime arguments."""
    setup, params = fitted
    cache = PlanCache(max_plans=8)
    srv = ServeCollab.from_setup(setup, params, max_batch=8, cache=cache)
    rng = np.random.default_rng(4)
    # groups 1 (3 users) and 2 (4 users) both pad to T=4: same bucket
    srv.submit(rng.standard_normal((8, M_RAW)), 1, 0)
    srv.serve()
    misses = cache.stats()["misses"]
    srv.submit(rng.standard_normal((8, M_RAW)), 2, 3)
    out = srv.serve()
    assert cache.stats()["misses"] == misses       # shared executable
    assert set(out.status.values()) == {"done"}


def test_warm_mixed_traffic_compiles_nothing(fitted):
    """Acceptance bar: steady-state serving across >=3 groups with
    heterogeneous request widths triggers exactly 0 executable builds."""
    setup, params = fitted
    srv = ServeCollab.from_setup(setup, params, max_batch=16)

    def sweep():
        # same stream both passes: tail-batch pow2 buckets depend on the
        # traffic, so the warm pass replays the cold pass's pattern
        rng = np.random.default_rng(5)
        for _ in range(25):
            g = int(rng.integers(0, setup.num_groups))
            u = int(rng.integers(0, setup.num_users(g)))
            srv.submit(rng.standard_normal(
                (int(rng.integers(1, 20)), M_RAW)), g, u)
        return srv.serve()

    sweep()                                        # cold: builds the buckets
    with CompileCounter() as cc:
        out = sweep()                              # warm: must build nothing
    assert cc.count == 0, f"warm sweep compiled {cc.count} executables"
    assert set(out.status.values()) == {"done"}


def test_no_tenant_data_baked_into_step(fitted):
    """Tenant tables and model params are runtime ARGUMENTS of the resident
    step — the lowered artifact must contain no large dense constants."""
    setup, params = fitted
    srv = ServeCollab.from_setup(setup, params, max_batch=16)
    for g in range(setup.num_groups):
        assert_no_baked_data(srv.lower_step(g, 16))


def test_live_onboarding_serves_new_tenant(fitted):
    setup, params = fitted
    srv = ServeCollab.from_setup(setup, params, max_batch=16)
    rng = np.random.default_rng(6)
    j = srv.onboard_user(0, rng.standard_normal((30, M_RAW)),
                         rng.standard_normal((30, 1)))
    x = rng.standard_normal((6, M_RAW))
    req = srv.submit(x, 0, j)
    out = srv.serve()
    np.testing.assert_allclose(out[req.rid],
                               _direct(srv.setup, params, 0, j, x),
                               rtol=0, atol=2e-5)
    i = srv.onboard_silo([rng.standard_normal((25, M_RAW)) for _ in range(2)],
                         [rng.standard_normal((25, 1)) for _ in range(2)])
    x2 = rng.standard_normal((4, M_RAW))
    r2 = srv.submit(x2, i, 1)
    out2 = srv.serve()
    np.testing.assert_allclose(out2[r2.rid],
                               _direct(srv.setup, params, i, 1, x2),
                               rtol=0, atol=2e-5)


def test_submit_validates_tenant(fitted):
    setup, params = fitted
    srv = ServeCollab.from_setup(setup, params)
    with pytest.raises(ValueError, match="unknown group"):
        srv.submit(np.zeros((2, M_RAW)), 99, 0)
    with pytest.raises(ValueError, match="unknown user"):
        srv.submit(np.zeros((2, M_RAW)), 0, 99)


def test_single_row_promotes(fitted):
    setup, params = fitted
    srv = ServeCollab.from_setup(setup, params)
    rng = np.random.default_rng(7)
    x = rng.standard_normal(M_RAW)                 # (m,) vector request
    req = srv.submit(x, 0, 0)
    out = srv.serve()
    assert out[req.rid].shape[0] == 1
    np.testing.assert_allclose(
        out[req.rid], _direct(setup, params, 0, 0, x[None, :]),
        rtol=0, atol=2e-5)


def test_explicit_requests_and_rids(fitted):
    setup, params = fitted
    srv = ServeCollab.from_setup(setup, params)
    rng = np.random.default_rng(8)
    reqs = [CollabRequest(rid=100 + k, group=0, user=0,
                          x=rng.standard_normal((3, M_RAW)))
            for k in range(3)]
    out = srv.serve(reqs)
    assert sorted(out) == [100, 101, 102]
    assert all(s == "done" for s in out.status.values())
