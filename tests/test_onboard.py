"""Incremental onboarding == from-scratch protocol recompute (DESIGN.md §10).

The whole point of the blocked-Gram / cached-factor path is that admitting
a tenant onto a live deployment produces THE SAME collaboration solve a
full `run_protocol` over all tenants would — the only thing shared is the
anchor (fixed once at deployment, passed via `run_protocol(anchor=...)`).
Property-style sweep: ragged per-user shapes, several group layouts, both
backends, user- and silo-onboarding, and repeated onboarding (error must
not compound past the bar).

Bars: 1e-8 for the host backend (both paths are f64 LAPACK — agreement is
near-exact), 1e-5 for the device backend (fp32 Gram/eigh/QR arithmetic).
"""
import numpy as np
import pytest

from repro.core import protocol

BACKENDS = [("host", 1e-8), ("device", 1e-5)]


def _mkdata(rng, counts, m, lo=20, hi=45):
    Xs = [[rng.standard_normal((int(rng.integers(lo, hi)), m))
           for _ in range(c)] for c in counts]
    Ys = [[rng.standard_normal((x.shape[0], 1)) for x in row] for row in Xs]
    return Xs, Ys


def _assert_setups_match(inc, ref, tol):
    """Incremental setup vs from-scratch reference: Z, every G, every X̂."""
    scale = max(1.0, float(np.abs(ref.Z).max()))
    assert np.abs(np.asarray(inc.Z) - np.asarray(ref.Z)).max() / scale < tol
    assert inc.num_groups == ref.num_groups
    for i in range(ref.num_groups):
        assert inc.num_users(i) == ref.num_users(i)
        for j in range(ref.num_users(i)):
            g_inc, g_ref = np.asarray(inc.Gs[i][j]), np.asarray(ref.Gs[i][j])
            s = max(1.0, float(np.abs(g_ref).max()))
            assert np.abs(g_inc - g_ref).max() / s < tol, (i, j)
        x_inc, x_ref = np.asarray(inc.collab_X[i]), np.asarray(ref.collab_X[i])
        assert x_inc.shape == x_ref.shape
        s = max(1.0, float(np.abs(x_ref).max()))
        assert np.abs(x_inc - x_ref).max() / s < tol, i
        np.testing.assert_allclose(inc.collab_Y[i], ref.collab_Y[i])


@pytest.mark.parametrize("backend,tol", BACKENDS)
@pytest.mark.parametrize("counts", [[2, 3], [3, 1, 2]])
def test_onboard_user_matches_full_recompute(backend, tol, counts):
    rng = np.random.default_rng(hash((backend, len(counts))) % 2**31)
    m = 7
    Xs, Ys = _mkdata(rng, counts, m)
    Xn = rng.standard_normal((33, m))
    Yn = rng.standard_normal((33, 1))
    kw = dict(m_tilde=4, anchor_r=120, seed=3, svd_backend=backend)

    setup = protocol.run_protocol(Xs, Ys, onboard=True, **kw)
    tgt = int(rng.integers(0, len(counts)))
    j = setup.onboard_user(tgt, Xn, Yn)
    assert j == counts[tgt]

    Xs2 = [list(row) for row in Xs]
    Ys2 = [list(row) for row in Ys]
    Xs2[tgt].append(Xn)
    Ys2[tgt].append(Yn)
    ref = protocol.run_protocol(Xs2, Ys2, anchor=setup.anchor, **kw)
    _assert_setups_match(setup, ref, tol)


@pytest.mark.parametrize("backend,tol", BACKENDS)
def test_onboard_silo_matches_full_recompute(backend, tol):
    rng = np.random.default_rng(11)
    m = 6
    Xs, Ys = _mkdata(rng, [2, 2], m)
    Xn = [rng.standard_normal((int(rng.integers(25, 40)), m))
          for _ in range(3)]
    Yn = [rng.standard_normal((x.shape[0], 1)) for x in Xn]
    kw = dict(m_tilde=4, anchor_r=100, seed=0, svd_backend=backend)

    setup = protocol.run_protocol(Xs, Ys, onboard=True, **kw)
    i = setup.onboard_silo(Xn, Yn)
    assert i == 2

    ref = protocol.run_protocol(list(Xs) + [Xn], list(Ys) + [Yn],
                                anchor=setup.anchor, **kw)
    _assert_setups_match(setup, ref, tol)


@pytest.mark.parametrize("backend,tol", BACKENDS)
def test_repeated_onboarding_does_not_drift(backend, tol):
    """user, user, silo, user onto the growing deployment — the final state
    must still match ONE from-scratch solve (errors must not compound)."""
    rng = np.random.default_rng(21)
    m = 5
    Xs, Ys = _mkdata(rng, [2, 2], m)
    kw = dict(m_tilde=3, anchor_r=90, seed=7, svd_backend=backend)
    setup = protocol.run_protocol(Xs, Ys, onboard=True, **kw)
    Xs2 = [list(r) for r in Xs]
    Ys2 = [list(r) for r in Ys]

    def new(n):
        return rng.standard_normal((n, m)), rng.standard_normal((n, 1))

    for tgt in (0, 1):
        x, y = new(int(rng.integers(20, 35)))
        setup.onboard_user(tgt, x, y)
        Xs2[tgt].append(x)
        Ys2[tgt].append(y)
    silo = [new(int(rng.integers(20, 35))) for _ in range(2)]
    setup.onboard_silo([x for x, _ in silo], [y for _, y in silo])
    Xs2.append([x for x, _ in silo])
    Ys2.append([y for _, y in silo])
    x, y = new(28)
    setup.onboard_user(2, x, y)                 # onto the onboarded silo
    Xs2[2].append(x)
    Ys2[2].append(y)

    ref = protocol.run_protocol(Xs2, Ys2, anchor=setup.anchor, **kw)
    _assert_setups_match(setup, ref, tol)


def test_onboard_requires_state():
    rng = np.random.default_rng(0)
    Xs, Ys = _mkdata(rng, [2], 5)
    setup = protocol.run_protocol(Xs, Ys, m_tilde=3, anchor_r=60, seed=0)
    with pytest.raises(RuntimeError, match="onboard=True"):
        setup.onboard_user(0, Xs[0][0], Ys[0][0])


def test_onboarded_comm_cost_is_one_round_trip():
    """The newcomer uploads its anchor image once and (conceptually)
    downloads the model once — exactly the paper's 2-communication claim;
    incumbents must not re-communicate."""
    rng = np.random.default_rng(4)
    Xs, Ys = _mkdata(rng, [2, 2], 5)
    setup = protocol.run_protocol(Xs, Ys, m_tilde=3, anchor_r=60, seed=0,
                                  onboard=True)
    n_events = len(setup.comm.events)
    setup.onboard_user(0, rng.standard_normal((25, 5)),
                       rng.standard_normal((25, 1)))
    new_events = setup.comm.events[n_events:]
    uploads = [e for e in new_events if e.src.startswith("user")]
    # exactly one user-originated upload: the newcomer's intermediates —
    # incumbents communicate nothing (their f_j never re-fits)
    assert len(uploads) == 1
    assert uploads[0].src == "user(0,2)"
