"""Collaboration-representation protocol: Theorem 1 (property-based),
backend agreement, least-squares correctness."""
import numpy as np
import pytest
from _prop import given, settings, st

from repro.core import collab
from repro.core.mappings import fit_mapping
from repro.core.protocol import run_protocol


def _split(X, Y, d, c, n_ij):
    Xs, Ys, k = [], [], 0
    for i in range(d):
        gx, gy = [], []
        for _ in range(c):
            gx.append(X[k * n_ij:(k + 1) * n_ij])
            gy.append(Y[k * n_ij:(k + 1) * n_ij])
            k += 1
        Xs.append(gx)
        Ys.append(gy)
    return Xs, Ys


@settings(max_examples=20, deadline=None)
@given(
    d=st.integers(2, 4),
    c=st.integers(1, 3),
    m=st.integers(6, 16),
    mt_frac=st.floats(0.3, 0.9),
    seed=st.integers(0, 10_000),
)
def test_theorem1_same_range_maps_give_exact_alignment(d, c, m, mt_frac, seed):
    """Theorem 1: linear f_j^(i) with identical range + rank(A F) = m̃
    ==> X̂ = X F exactly (alignment residual 0, collaboration reps equal a
    single global linear map of the raw data)."""
    rng = np.random.default_rng(seed)
    m_tilde = max(2, int(m * mt_frac))
    n_ij = 12
    n = n_ij * d * c
    X = rng.standard_normal((n, m))
    Y = rng.standard_normal((n, 1))
    Xs, Ys = _split(X, Y, d, c, n_ij)

    # same-range maps: F_j = F_base @ (random nonsingular E_j)
    F_base = rng.standard_normal((m, m_tilde))
    setups = []
    Es = [[rng.standard_normal((m_tilde, m_tilde)) +
           np.eye(m_tilde) * m_tilde for _ in range(c)] for _ in range(d)]
    # run protocol with per-user fixed W = F_base E_j and NO centering
    from repro.core.mappings import LinearMap
    import repro.core.protocol as proto

    anchors = rng.standard_normal((2000, m))
    inter_A, inter_X = [], []
    mappings = []
    for i in range(d):
        row_a, row_x, row_f = [], [], []
        for j in range(c):
            W = F_base @ Es[i][j]
            f = LinearMap(mu=np.zeros(m), W=W)
            row_f.append(f)
            row_a.append(f(anchors))
            row_x.append(f(Xs[i][j]))
        inter_A.append(row_a)
        inter_X.append(row_x)
        mappings.append(row_f)

    bases = [collab.intra_group_basis(inter_A[i], m_tilde, seed + i)
             for i in range(d)]
    target = collab.central_target(bases, m_tilde, seed + 99)
    res = []
    Gs = []
    for i in range(d):
        for j in range(c):
            G = collab.solve_G(inter_A[i][j], target.Z)
            Gs.append((i, j, G))
            res.append(collab.alignment_residual(inter_A[i][j], G, target.Z))
    assert max(res) < 1e-6, f"Theorem-1 alignment violated: {max(res)}"

    # X̂ = X F for one global F
    F = mappings[0][0].W @ Gs[0][2]
    for (i, j, G) in Gs:
        Xhat = inter_X[i][j] @ G
        np.testing.assert_allclose(Xhat, Xs[i][j] @ F, atol=1e-6 * n, rtol=1e-5)


def test_different_range_maps_are_not_exact():
    """Sanity: with generic per-user PCA+rotation maps, alignment is
    approximate (nonzero residual) — Theorem 1's conditions matter."""
    rng = np.random.default_rng(0)
    X = rng.standard_normal((120, 10))
    Y = rng.standard_normal((120, 1))
    Xs, Ys = _split(X, Y, 2, 2, 30)
    setup = run_protocol(Xs, Ys, m_tilde=4, anchor_r=500, seed=0)
    # reconstruct residuals from the setup by re-solving
    assert setup.collab_X[0].shape == (60, 4)


def test_backend_agreement_host_vs_tpu_gram():
    rng = np.random.default_rng(1)
    A = rng.standard_normal((400, 24))
    U1, s1, V1 = collab.topk_svd(A, 8, "host")
    U2, s2, V2 = collab.topk_svd(A, 8, "tpu")
    np.testing.assert_allclose(s1, s2, rtol=1e-3)
    # subspaces agree (up to sign): |U1^T U2| ~ I
    M = np.abs(U1.T @ U2)
    np.testing.assert_allclose(M, np.eye(8), atol=1e-2)


def test_solve_G_is_least_squares():
    rng = np.random.default_rng(2)
    A = rng.standard_normal((50, 6))
    Z = rng.standard_normal((50, 4))
    G = collab.solve_G(A, Z)
    # residual orthogonal to col(A)
    r = A @ G - Z
    np.testing.assert_allclose(A.T @ r, np.zeros((6, 4)), atol=1e-9)


def test_obfuscation_keeps_span():
    """B̃ = U C1 must span the same subspace as U (C1 nonsingular)."""
    rng = np.random.default_rng(3)
    anchors = [rng.standard_normal((300, 5)) for _ in range(3)]
    gb = collab.intra_group_basis(anchors, 4, seed=0)
    A = np.concatenate(anchors, axis=1)
    U, _, _ = collab.topk_svd(A, 4, "host")
    # projection of B onto span(U) recovers B
    P = U @ U.T
    np.testing.assert_allclose(P @ gb.B, gb.B, atol=1e-8)
