"""Protocol invariants that must hold on BOTH collaboration backends:
the paper's exactly-two-communications claim and Theorem-1 exact alignment
when m̂ ≤ rank(Ã)."""
import numpy as np
import pytest

from repro.core import collab
from repro.core.protocol import finalize_user_models, run_protocol
from repro.data.partition import split_iid
from repro.data.tabular import make_dataset, train_test_split

BACKENDS = ["host", "device"]


@pytest.fixture(scope="module")
def partitions():
    ds = make_dataset("battery_small", n=900, seed=0)
    (Xtr, Ytr), _ = train_test_split(ds, 400, 400, seed=0)
    return split_iid(Xtr, Ytr, d=2, c=[2, 2], n_ij=80, seed=0)


@pytest.mark.parametrize("backend", BACKENDS)
def test_user_round_trips_exactly_two(partitions, backend):
    Xs, Ys = partitions
    setup = run_protocol(Xs, Ys, m_tilde=4, anchor_r=600, seed=0,
                         svd_backend=backend)
    finalize_user_models(setup, h=lambda z: z)
    trips = setup.comm.user_round_trips()
    assert len(trips) == 4
    assert all(v == 2 for v in trips.values()), trips


@pytest.mark.parametrize("backend", BACKENDS)
def test_theorem1_alignment_residual_near_zero(backend):
    """Same-range maps (shared fixed W) + m̂ = m̃ ≤ rank(Ã): eq. (3) is
    solvable exactly, so the alignment residual vanishes (fp32 on device)."""
    rng = np.random.default_rng(0)
    d, c, n_ij, m, m_tilde = 3, 2, 40, 12, 5
    X = rng.standard_normal((d * c * n_ij, m))
    Y = rng.standard_normal((d * c * n_ij, 1))
    Xs = [[X[(i * c + j) * n_ij:(i * c + j + 1) * n_ij] for j in range(c)]
          for i in range(d)]
    # zero per-user means so the fitted maps f_j(x) = (x − μ_j) W share one
    # exact range (Theorem 1's same-function-range condition)
    Xs = [[x - x.mean(axis=0, keepdims=True) for x in g] for g in Xs]
    Ys = [[Y[(i * c + j) * n_ij:(i * c + j + 1) * n_ij] for j in range(c)]
          for i in range(d)]
    W = rng.standard_normal((m, m_tilde))
    setup = run_protocol(Xs, Ys, m_tilde=m_tilde, anchor_r=500,
                         mapping_kind="fixed", fixed_W=W, seed=0,
                         svd_backend=backend)
    tol = 1e-8 if backend == "host" else 1e-4
    for i in range(d):
        for j in range(c):
            A_ij = setup.mappings[i][j](setup.anchor)
            res = collab.alignment_residual(A_ij, setup.Gs[i][j], setup.Z)
            assert res < tol, (backend, i, j, res)


@pytest.mark.parametrize("backend", BACKENDS)
def test_collab_layer_theorem1_direct(backend):
    """Same invariant exercised through the collab-layer API (batched
    intra_group_bases + solve_G_all) rather than run_protocol."""
    rng = np.random.default_rng(1)
    d, c, m_tilde, r = 2, 3, 4, 400
    F = rng.standard_normal((10, m_tilde))
    anchor = rng.standard_normal((r, 10))
    groups = [[anchor @ F @ (rng.standard_normal((m_tilde, m_tilde)) +
                             np.eye(m_tilde) * m_tilde)
               for _ in range(c)] for _ in range(d)]
    bases = collab.intra_group_bases(groups, m_tilde,
                                     seeds=[7 * i for i in range(d)],
                                     backend=backend)
    target = collab.central_target(bases, m_tilde, seed=99, backend=backend)
    flat = [a for g in groups for a in g]
    Gs = collab.solve_G_all(flat, target.Z, backend=backend)
    tol = 1e-6 if backend == "host" else 1e-3
    for A, G in zip(flat, Gs):
        assert collab.alignment_residual(A, G, target.Z) < tol
