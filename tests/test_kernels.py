"""Per-kernel validation: Pallas (interpret mode) vs pure-jnp oracles,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import ops as fa_ops, ref as fa_ref
from repro.kernels.gram import ops as gram_ops, ref as gram_ref
from repro.kernels.rwkv6 import ops as rwkv_ops, ref as rwkv_ref


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 4, 4, 64, 32),       # MHA
    (2, 8, 2, 128, 64),      # GQA 4:1
    (1, 8, 8, 256, 128),     # long-ish, MXU-aligned head
    (2, 4, 1, 64, 64),       # MQA
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, H, KV, S, hd, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out_ref = fa_ops.flash_attention(q, k, v, backend="ref")
    out_pal = fa_ops.flash_attention(q, k, v, backend="interpret")
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out_pal, np.float32),
                               np.asarray(out_ref, np.float32),
                               atol=tol, rtol=tol)


@pytest.mark.parametrize("window,softcap", [(0, 0.0), (32, 0.0), (0, 50.0),
                                            (48, 30.0)])
def test_flash_attention_variants(window, softcap):
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    B, S, H, KV, hd = 2, 128, 8, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, KV, hd))
    v = jax.random.normal(ks[2], (B, S, KV, hd))
    a = fa_ops.flash_attention(q, k, v, window=window, softcap=softcap,
                               backend="ref")
    b = fa_ops.flash_attention(q, k, v, window=window, softcap=softcap,
                               backend="interpret")
    np.testing.assert_allclose(np.asarray(b), np.asarray(a), atol=2e-5, rtol=2e-5)


def test_flash_attention_blocks_smaller_than_seq():
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    B, S, H, hd = 1, 512, 2, 64
    q = jax.random.normal(ks[0], (B, S, H, hd))
    k = jax.random.normal(ks[1], (B, S, H, hd))
    v = jax.random.normal(ks[2], (B, S, H, hd))
    from repro.kernels.flash_attention.kernel import flash_attention_pallas
    out = flash_attention_pallas(q.swapaxes(1, 2), k.swapaxes(1, 2),
                                 v.swapaxes(1, 2), block_q=128, block_k=128,
                                 interpret=True).swapaxes(1, 2)
    ref = fa_ops.flash_attention(q, k, v, backend="ref")
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


# --------------------------------------------------------------------------
# gram
# --------------------------------------------------------------------------

@pytest.mark.parametrize("r,m", [(100, 32), (1000, 300), (513, 129), (64, 512)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_gram_shapes(r, m, dtype):
    a = jax.random.normal(jax.random.PRNGKey(0), (r, m), dtype)
    g_ref = gram_ref.gram_reference(a)
    g_pal = gram_ops.gram(a, backend="interpret")
    tol = 5e-2 if dtype == jnp.bfloat16 else 5e-3
    np.testing.assert_allclose(np.asarray(g_pal), np.asarray(g_ref),
                               atol=tol * r ** 0.5, rtol=tol)


def test_gram_eigh_topk_matches_svd():
    a = jax.random.normal(jax.random.PRNGKey(1), (500, 80))
    U, s, V = gram_ops.gram_eigh_topk(a, 10, backend="ref")
    s_ref = np.linalg.svd(np.asarray(a), compute_uv=False)[:10]
    np.testing.assert_allclose(np.asarray(s), s_ref, rtol=1e-4)
    # U orthonormal, A V ~ U s
    np.testing.assert_allclose(np.asarray(U.T @ U), np.eye(10), atol=1e-4)
    np.testing.assert_allclose(np.asarray(a @ V), np.asarray(U * s[None, :]),
                               atol=1e-3)


# --------------------------------------------------------------------------
# rwkv6
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,S,H,K,V", [
    (1, 32, 2, 16, 16), (2, 64, 3, 16, 24), (1, 48, 1, 64, 64),
])
def test_wkv6_chunked_vs_scan(B, S, H, K, V):
    ks = jax.random.split(jax.random.PRNGKey(0), 5)
    r = jax.random.normal(ks[0], (B, S, H, K))
    k = jax.random.normal(ks[1], (B, S, H, K))
    v = jax.random.normal(ks[2], (B, S, H, V))
    lw = -jnp.exp(jnp.clip(jax.random.normal(ks[3], (B, S, H, K)), -8, 1.6))
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    o_scan = rwkv_ref.wkv6_scan(r, k, v, lw, u)
    o_chunk = rwkv_ref.wkv6_chunked(r, k, v, lw, u, chunk=16)
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_scan),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("S", [32, 64, 80])   # incl. non-multiple of 16
def test_wkv6_pallas_interpret(S):
    B, H, K, V = 2, 2, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    r = jax.random.normal(ks[0], (B, S, H, K))
    k = jax.random.normal(ks[1], (B, S, H, K))
    v = jax.random.normal(ks[2], (B, S, H, V))
    lw = -jnp.exp(jnp.clip(jax.random.normal(ks[3], (B, S, H, K)), -8, 1.6))
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    o_scan = rwkv_ref.wkv6_scan(r, k, v, lw, u)
    if S % 16 == 0:
        o_pal = rwkv_ops.wkv6(r, k, v, lw, u, backend="interpret")
        np.testing.assert_allclose(np.asarray(o_pal), np.asarray(o_scan),
                                   atol=2e-4, rtol=2e-3)
    o_chunk = rwkv_ops.wkv6(r, k, v, lw, u, backend="chunked")
    np.testing.assert_allclose(np.asarray(o_chunk), np.asarray(o_scan),
                               atol=2e-4, rtol=2e-3)


def test_wkv6_chunked_final_state():
    B, S, H, K, V = 1, 48, 2, 16, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r = jax.random.normal(ks[0], (B, S, H, K))
    k = jax.random.normal(ks[1], (B, S, H, K))
    v = jax.random.normal(ks[2], (B, S, H, V))
    lw = -jnp.exp(jnp.clip(jax.random.normal(ks[3], (B, S, H, K)), -8, 1.6))
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    _, state = rwkv_ref.wkv6_chunked(r, k, v, lw, u, chunk=16,
                                     return_state=True)
    # evolve the exact scan one more step and compare the o produced from
    # the chunked state
    ks2 = jax.random.split(jax.random.PRNGKey(3), 4)
    r2 = jax.random.normal(ks2[0], (B, 1, H, K))
    k2 = jax.random.normal(ks2[1], (B, 1, H, K))
    v2 = jax.random.normal(ks2[2], (B, 1, H, V))
    lw2 = -jnp.exp(jnp.clip(jax.random.normal(ks2[3], (B, 1, H, K)), -8, 1.6))
    full = rwkv_ref.wkv6_scan(jnp.concatenate([r, r2], 1),
                              jnp.concatenate([k, k2], 1),
                              jnp.concatenate([v, v2], 1),
                              jnp.concatenate([lw, lw2], 1), u)
    kv = jnp.einsum("bhk,bhv->bhkv", k2[:, 0], v2[:, 0])
    o_next = jnp.einsum("bhk,bhkv->bhv", r2[:, 0],
                        state + u[None, :, :, None] * kv)
    np.testing.assert_allclose(np.asarray(o_next), np.asarray(full[:, -1]),
                               atol=2e-4, rtol=2e-3)
