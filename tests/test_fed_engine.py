"""FedEngine tier: the compiled scan engine must be a drop-in replacement
for the paper-faithful host loop — same seed, same schedule, same results —
and the padding masks must provably keep zero-sample slots out of every
average (DESIGN.md §4)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _prop import given, settings, st

from repro.core import federated
from repro.core.baselines import sgd_train
from repro.core.federated import pad_silo_data, run_federated
from repro.data.partition import split_dirichlet, split_iid
from repro.data.tabular import make_dataset, train_test_split
from repro.models import mlp
from repro.optim import adamw, sgd


def _reg_loss(p, x, y):
    return mlp.mlp_per_example_loss(p, x, y, "regression")


def _cls_loss(p, x, y):
    return mlp.mlp_per_example_loss(p, x, y, "classification")


def _linear_silos(sizes, m=4, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, 1))
    out = []
    for k, n in enumerate(sizes):
        r = np.random.default_rng(seed * 97 + k + 1)
        X = r.standard_normal((n, m))
        out.append((X, X @ w + 0.01 * r.standard_normal((n, 1))))
    return out


def _params(m=4, out=1, seed=0):
    return mlp.init_mlp_params(jax.random.PRNGKey(seed), m, (8,), out)


def _max_rel_diff(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))) /
              (np.max(np.abs(np.asarray(x))) + 1e-12))
        for x, y in zip(la, lb))


# --------------------------------------------------------------------------
# host == scan: every aggregator, ragged silos included
# --------------------------------------------------------------------------

@pytest.mark.parametrize("aggregator", ["fedavg", "fedprox", "fedsgd"])
@pytest.mark.parametrize("sizes", [(32, 32), (40, 28, 52)],
                         ids=["equal", "ragged"])
def test_scan_matches_host_params_and_trajectory(aggregator, sizes):
    silos = _linear_silos(list(sizes), seed=3)
    params = _params(seed=1)
    kw = dict(opt=adamw(1e-2), rounds=4, local_epochs=2, batch_size=16,
              aggregator=aggregator,
              fedprox_mu=0.1 if aggregator == "fedprox" else 0.0, seed=7)
    host = run_federated(_reg_loss, params, silos, engine="host", **kw)
    scan = run_federated(_reg_loss, params, silos, engine="scan", **kw)
    assert _max_rel_diff(host.params, scan.params) < 1e-4
    for h, s in zip(host.history, scan.history):
        assert abs(h["loss"] - s["loss"]) < 1e-4 * max(1.0, abs(h["loss"]))


@pytest.mark.parametrize("split", ["iid", "dirichlet"])
def test_scan_matches_host_on_paper_partitions(split):
    """Exp-I-shaped data (classification, Dirichlet non-IID included):
    engines agree on the real protocol inputs, not just toy regressions."""
    ds = make_dataset("human_activity", n=2200, seed=0)
    (Xtr, Ytr), _ = train_test_split(ds, 800, 400, seed=0)
    if split == "iid":
        Xs, Ys = split_iid(Xtr, Ytr, d=2, c=[2, 2], n_ij=100, seed=0)
    else:
        Xs, Ys = split_dirichlet(Xtr, Ytr, d=2, c=[2, 2], n_ij=100,
                                 alpha=0.3, seed=0)
    silos = [(Xs[i][j], Ys[i][j]) for i in range(2) for j in range(2)]
    params = mlp.init_mlp_params(jax.random.PRNGKey(0), Xtr.shape[1], (16,), 5)
    kw = dict(opt=adamw(1e-3), rounds=3, local_epochs=2, batch_size=32, seed=0)
    host = run_federated(_cls_loss, params, silos, engine="host", **kw)
    scan = run_federated(_cls_loss, params, silos, engine="scan", **kw)
    assert _max_rel_diff(host.params, scan.params) < 1e-4


def test_scan_matches_host_with_eval_and_sgd_train():
    """The d=1 degenerate case (sgd_train) and the eval_fn carry path."""
    X, Y = _linear_silos([100], seed=5)[0]
    params = _params(seed=2)
    ev = lambda p: {"metric": float(jnp.mean(jnp.abs(
        jax.tree_util.tree_leaves(p)[0])))}
    ph, hh = sgd_train(_reg_loss, params, X, Y, opt=adamw(1e-2), epochs=3,
                       eval_fn=ev, engine="host")
    ps, hs = sgd_train(_reg_loss, params, X, Y, opt=adamw(1e-2), epochs=3,
                       eval_fn=ev, engine="scan")
    assert _max_rel_diff(ph, ps) < 1e-4
    assert len(hh) == len(hs) == 3
    for a, b in zip(hh, hs):
        assert a["epoch"] == b["epoch"]
        assert abs(a["metric"] - b["metric"]) < 1e-5


@pytest.mark.parametrize("eval_chunk", [4, 8],
                         ids=["two-dispatches", "one-dispatch"])
def test_streamed_eval_history_matches_stacked(eval_chunk):
    """Regression for the bounded-memory eval path: the chunked streamed
    history must match (a) the legacy collect="stack" plan that
    materialized a (rounds, |params|) stack on device, and (b) the host
    engine — both per-round losses and eval_fn outputs. eval_chunk=4 with
    rounds=6 exercises the ragged final dispatch (4 + 2)."""
    silos = _linear_silos([40, 28, 52], seed=3)
    params = _params(seed=1)
    ev = lambda p: {"w0": float(np.asarray(
        jax.tree_util.tree_leaves(p)[0]).ravel()[0])}
    kw = dict(opt=adamw(1e-2), rounds=6, local_epochs=2, batch_size=16,
              seed=7)
    host = run_federated(_reg_loss, params, silos, engine="host", eval_fn=ev,
                         **kw)
    scan = run_federated(_reg_loss, params, silos, engine="scan", eval_fn=ev,
                         eval_chunk=eval_chunk, **kw)
    # the OLD stacked path, driven through the same runner
    padded = pad_silo_data(silos, 16)
    batch_loss = federated._make_batch_loss(_reg_loss, True, 0.0)
    stacked_plan = federated.make_fl_plan(
        num_silos=padded.num_silos, num_batches=padded.num_batches,
        batch_size=padded.batch_size, opt=adamw(1e-2), batch_loss=batch_loss,
        rounds=6, local_epochs=2, collect="stack", masked=padded.has_padding)
    legacy = federated._run_scan(
        batch_loss, params, padded, opt=adamw(1e-2), rounds=6, local_epochs=2,
        aggregator="fedavg", seed=7, eval_fn=ev, per_example=True,
        reset_opt=True, plan=stacked_plan)
    assert len(scan.history) == len(legacy.history) == 6
    for s, l, h in zip(scan.history, legacy.history, host.history):
        assert abs(s["w0"] - l["w0"]) < 1e-6
        assert abs(s["loss"] - l["loss"]) < 1e-6 * max(1.0, abs(l["loss"]))
        assert abs(s["w0"] - h["w0"]) < 1e-4
    assert _max_rel_diff(scan.params, legacy.params) < 1e-6
    assert _max_rel_diff(scan.params, host.params) < 1e-4


def test_momentum_optimizer_state_vmaps_through_scan():
    silos = _linear_silos([24, 24], seed=9)
    params = _params(seed=3)
    kw = dict(opt=sgd(1e-2, momentum=0.9), rounds=3, local_epochs=2,
              batch_size=8, seed=1)
    host = run_federated(_reg_loss, params, silos, engine="host", **kw)
    scan = run_federated(_reg_loss, params, silos, engine="scan", **kw)
    assert _max_rel_diff(host.params, scan.params) < 1e-4


# --------------------------------------------------------------------------
# loss reporting: sample-weighted mean of per-silo final-epoch losses
# --------------------------------------------------------------------------

def test_round_loss_is_sample_weighted_over_silos():
    """Regression for the old bug (last minibatch of the LAST silo only):
    duplicating a silo's data must not change the reported round loss, and
    the loss must weight silos by sample count."""
    silos = _linear_silos([32, 64], seed=11)
    params = _params(seed=4)
    kw = dict(opt=adamw(1e-3), rounds=1, local_epochs=1, batch_size=16, seed=0)
    res = run_federated(_reg_loss, params, silos, engine="host", **kw)
    # recompute by hand from the engine's own schedule
    padded = pad_silo_data(silos, 16)
    perms = np.asarray(federated.round_perms(
        jax.random.PRNGKey(0), 0, 2, 1, padded.n_slots))
    num = den = 0.0
    opt = adamw(1e-3)
    for i in range(2):
        p, o = params, opt.init(params)
        s_num = s_den = 0.0
        for b in perms[i, 0].reshape(-1, 16):
            x, y, w = (jnp.asarray(padded.X[i][b]), jnp.asarray(padded.Y[i][b]),
                       jnp.asarray(padded.w[i][b]))
            l = _reg_loss(p, x, y)
            bl = float(jnp.sum(w * l) / jnp.maximum(jnp.sum(w), 1.0))
            grads = jax.grad(lambda pp: jnp.sum(w * _reg_loss(pp, x, y)) /
                             jnp.maximum(jnp.sum(w), 1.0))(p)
            upd, o = opt.update(grads, o, p)
            p = jax.tree.map(lambda a, u: a + u, p, upd)
            s_num += bl * float(w.sum())
            s_den += float(w.sum())
        num += padded.sizes[i] * (s_num / s_den)
        den += padded.sizes[i]
    assert abs(res.history[0]["loss"] - num / den) < 1e-5


# --------------------------------------------------------------------------
# padding property: masks never leak zero-sample gradients
# --------------------------------------------------------------------------

@settings(deadline=None, max_examples=6)
@given(n1=st.integers(5, 40), n2=st.integers(5, 40),
       fill=st.sampled_from([123.0, -999.0, 1e4]))
def test_padding_fill_never_leaks_into_training(n1, n2, fill):
    """Whatever garbage sits in padded X slots, masked losses/grads must be
    bit-identical to zero-fill — i.e. padding contributes exactly nothing."""
    silos = _linear_silos([n1, n2], seed=n1 * 100 + n2)
    params = _params(seed=5)
    kw = dict(opt=adamw(1e-2), rounds=2, local_epochs=2, batch_size=16, seed=2)
    for engine in ("host", "scan"):
        clean = run_federated(_reg_loss, params, silos, engine=engine,
                              pad_fill=0.0, **kw)
        dirty = run_federated(_reg_loss, params, silos, engine=engine,
                              pad_fill=fill, **kw)
        for a, b in zip(jax.tree_util.tree_leaves(clean.params),
                        jax.tree_util.tree_leaves(dirty.params)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        for h, g in zip(clean.history, dirty.history):
            assert h["loss"] == g["loss"]


def test_all_padding_batch_is_exact_noop():
    """A batch with ZERO real samples must leave params AND optimizer state
    untouched — without the masked-step guard Adam would still advance its
    step counter, decay momentum, and coast parameters, giving small ragged
    silos extra effective steps (DESIGN.md §4 rule 2)."""
    params = _params(seed=7)
    opt = adamw(1e-2)
    batch_loss = federated._make_batch_loss(_reg_loss, True, 0.0)
    step = federated._make_sgd_step(batch_loss, opt, masked=True)
    x = jnp.full((8, 4), 1e3)                            # garbage padding
    y = jnp.zeros((8, 1))
    w0 = jnp.zeros((8,))
    # warm the optimizer state so momentum could coast if unguarded
    state = opt.init(params)
    p1, s1, _ = step(params, state, jnp.ones((8, 4)), y, jnp.ones((8,)),
                     params)
    p2, s2, loss = step(p1, s1, x, y, w0, params)
    assert float(loss) == 0.0
    for a, b in zip(jax.tree_util.tree_leaves(p1),
                    jax.tree_util.tree_leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree_util.tree_leaves(s1),
                    jax.tree_util.tree_leaves(s2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_tiny_silo_takes_only_real_steps():
    """Silo with 1 real sample in 64 slots: its local training for one
    epoch is exactly ONE optimizer step on that sample, wherever the
    permutation lands it — engines agree and match the manual step."""
    silos = _linear_silos([1, 64], seed=13)
    params = _params(seed=8)
    kw = dict(opt=adamw(1e-2), rounds=1, local_epochs=1, batch_size=16,
              seed=4)
    host = run_federated(_reg_loss, params, silos, engine="host", **kw)
    scan = run_federated(_reg_loss, params, silos, engine="scan", **kw)
    assert _max_rel_diff(host.params, scan.params) < 1e-5
    # manual: silo-0 local params after one adam step on its single sample
    opt = adamw(1e-2)
    x, y = jnp.asarray(silos[0][0]), jnp.asarray(silos[0][1])
    grads = jax.grad(lambda p: jnp.mean(_reg_loss(p, x, y)))(params)
    upd, _ = opt.update(grads, opt.init(params), params)
    p0 = jax.tree.map(lambda a, u: a + u, params, upd)
    # recover silo-0 locals from the weighted mean: gp = (1*p0 + 64*p1)/65
    # → check gp is consistent with the manual p0 given engine-trained p1
    # (equivalently: train silo 0 alone and compare)
    solo = run_federated(_reg_loss, params, silos[:1], engine="host", **kw)
    assert _max_rel_diff(solo.params, p0) < 1e-5


def test_fedsgd_weighted_average_excludes_padding():
    """FedSGD full-batch gradients are masked means: a silo padded from 10
    to 40 slots must contribute the gradient of its 10 real samples only."""
    silos = _linear_silos([10, 40], seed=21)
    params = _params(seed=6)
    kw = dict(opt=sgd(1e-1), rounds=1, local_epochs=1, aggregator="fedsgd",
              seed=0)
    res = run_federated(_reg_loss, params, silos, engine="scan", **kw)
    # manual: per-silo mean grads on REAL rows, sample-weighted 10:40
    def silo_grad(X, Y):
        return jax.grad(lambda p: jnp.mean(_reg_loss(p, jnp.asarray(X),
                                                     jnp.asarray(Y))))(params)
    g = jax.tree.map(lambda a, b: (10 * a + 40 * b) / 50.0,
                     silo_grad(*silos[0]), silo_grad(*silos[1]))
    manual = jax.tree.map(lambda p, gg: p - 1e-1 * gg, params, g)
    assert _max_rel_diff(res.params, manual) < 1e-5


# --------------------------------------------------------------------------
# guard rails
# --------------------------------------------------------------------------

def test_scalar_loss_with_padding_raises():
    silos = _linear_silos([20, 30], seed=1)
    scalar = lambda p, x, y: mlp.mlp_loss(p, x, y, "regression")
    with pytest.raises(ValueError, match="per-example"):
        run_federated(scalar, _params(), silos, opt=adamw(1e-2), rounds=1,
                      local_epochs=1, batch_size=16)


def test_unknown_engine_and_aggregator_raise():
    silos = _linear_silos([16], seed=1)
    with pytest.raises(ValueError, match="engine"):
        run_federated(_reg_loss, _params(), silos, opt=adamw(1e-2), rounds=1,
                      local_epochs=1, engine="warp")
    with pytest.raises(ValueError, match="aggregator"):
        run_federated(_reg_loss, _params(), silos, opt=adamw(1e-2), rounds=1,
                      local_epochs=1, aggregator="fedfoo")
