"""Sharded-engine tier (DESIGN.md §7): the mesh-sharded FL plan must be a
drop-in for the single-device vmap plan — same schedule, same results —
with round-boundary psums as the ONLY collectives.

The in-process tests build a mesh over however many devices exist (1 on a
plain tier-1 run — plumbing only; 8 on the CI matrix leg that exports
XLA_FLAGS=--xla_force_host_platform_device_count=8 — real sharding). The
subprocess tests force 8 virtual devices regardless, so ragged / non
divisible silo counts and the collective-structure invariant are proven on
every run.
"""
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import federated
from repro.core.federated import (default_silo_axes, num_silo_shards,
                                  run_federated)
from repro.launch.mesh import make_host_mesh
from repro.models import mlp
from repro.optim import adamw, sgd

DEV = jax.device_count()


def _linear_silos(sizes, m=4, seed=0):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((m, 1))
    out = []
    for k, n in enumerate(sizes):
        r = np.random.default_rng(seed * 97 + k + 1)
        X = r.standard_normal((n, m))
        out.append((X, X @ w + 0.01 * r.standard_normal((n, 1))))
    return out


def _params(seed=0):
    return mlp.init_mlp_params(jax.random.PRNGKey(seed), 4, (8,), 1)


def _reg_loss(p, x, y):
    return mlp.mlp_per_example_loss(p, x, y, "regression")


def _max_rel_diff(a, b):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))) /
              (np.max(np.abs(np.asarray(x))) + 1e-12))
        for x, y in zip(la, lb))


KW = dict(opt=adamw(1e-2), rounds=3, local_epochs=2, batch_size=16,
          engine="scan", seed=7)


# --------------------------------------------------------------------------
# sharded == unsharded, in-process (real sharding on the 8-device CI leg)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("aggregator", ["fedavg", "fedprox", "fedsgd"])
def test_sharded_matches_unsharded_all_aggregators(aggregator):
    """Ragged silo count (d=3 — not divisible by any multi-device mesh):
    run_federated pads d up to the shard multiple with exact-no-op empty
    silos, so the sharded result matches the vmap plan ≤1e-5."""
    silos = _linear_silos([20, 13, 17], seed=3)
    params = _params(seed=1)
    kw = {**KW, "aggregator": aggregator,
          "fedprox_mu": 0.1 if aggregator == "fedprox" else 0.0}
    base = run_federated(_reg_loss, params, silos, **kw)
    sh = run_federated(_reg_loss, params, silos, mesh=make_host_mesh(model=1),
                       **kw)
    assert _max_rel_diff(base.params, sh.params) <= 1e-5
    for a, b in zip(base.history, sh.history):
        assert abs(a["loss"] - b["loss"]) <= 1e-5 * max(1.0, abs(a["loss"]))


def test_sharded_streamed_eval_matches_unsharded():
    """mesh= composes with eval_fn: the chunked streamed-eval path runs
    inside the shard_map and the per-round history still matches."""
    silos = _linear_silos([20, 13, 17], seed=5)
    params = _params(seed=2)
    ev = lambda p: {"w0": float(jnp.mean(jnp.abs(
        jax.tree_util.tree_leaves(p)[0])))}
    base = run_federated(_reg_loss, params, silos, eval_fn=ev, **KW)
    sh = run_federated(_reg_loss, params, silos, eval_fn=ev,
                       mesh=make_host_mesh(model=1), eval_chunk=2, **KW)
    assert len(sh.history) == KW["rounds"]
    for a, b in zip(base.history, sh.history):
        assert abs(a["w0"] - b["w0"]) <= 1e-5


def test_sharded_carries_opt_state_across_rounds():
    silos = _linear_silos([18, 25], seed=9)
    params = _params(seed=3)
    kw = {**KW, "opt": sgd(1e-2, momentum=0.9),
          "reset_opt_per_round": False}
    base = run_federated(_reg_loss, params, silos, **kw)
    sh = run_federated(_reg_loss, params, silos, mesh=make_host_mesh(model=1),
                       **kw)
    assert _max_rel_diff(base.params, sh.params) <= 1e-5


def test_mesh_requires_scan_engine():
    silos = _linear_silos([16], seed=1)
    with pytest.raises(ValueError, match="scan"):
        run_federated(_reg_loss, _params(), silos, opt=adamw(1e-2), rounds=1,
                      local_epochs=1, engine="host",
                      mesh=make_host_mesh(model=1))


def test_num_silo_shards_validates_axes():
    mesh = make_host_mesh(model=1)
    assert num_silo_shards(mesh) == mesh.devices.shape[0]
    assert default_silo_axes(mesh) == ("data",)
    with pytest.raises(ValueError, match="nope"):
        num_silo_shards(mesh, ("nope",))


@pytest.mark.skipif(DEV < 8, reason="needs 8 devices (CI sharded leg)")
def test_hierarchical_pod_data_mesh_matches_unsharded():
    """(2, 2, 2) pod/data/model mesh: the silo dim spans ("pod", "data")
    jointly (4 shards), aggregation is the two-level psum — intra-pod
    first, cross-pod second — and results still match the vmap plan."""
    devices = np.asarray(jax.devices()[:8]).reshape(2, 2, 2)
    mesh = jax.sharding.Mesh(devices, ("pod", "data", "model"))
    assert default_silo_axes(mesh) == ("pod", "data")
    assert num_silo_shards(mesh) == 4
    silos = _linear_silos([20, 13, 17], seed=3)
    params = _params(seed=1)
    base = run_federated(_reg_loss, params, silos, **KW)
    sh = run_federated(_reg_loss, params, silos, mesh=mesh, **KW)
    assert _max_rel_diff(base.params, sh.params) <= 1e-5


# --------------------------------------------------------------------------
# forced 8-virtual-device subprocess: ragged d, all aggregators, collective
# structure — proven even when the parent pytest runs on 1 device
# --------------------------------------------------------------------------

SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.analysis import assert_no_baked_data, collective_census
    from repro.core import federated
    from repro.core.federated import pad_silo_data, run_federated
    from repro.launch.mesh import make_host_mesh
    from repro.models import mlp
    from repro.optim import adamw

    assert jax.device_count() == 8

    def loss(p, x, y):
        return mlp.mlp_per_example_loss(p, x, y, "regression")

    rng = np.random.default_rng(0)
    w = rng.standard_normal((4, 1))
    silos = []
    for n in (12, 20, 9, 15, 11):           # d=5: ragged AND not divisible
        X = rng.standard_normal((n, 4))
        silos.append((X, X @ w + 0.01 * rng.standard_normal((n, 1))))
    params = mlp.init_mlp_params(jax.random.PRNGKey(0), 4, (8,), 1)
    mesh = make_host_mesh(model=1)          # (8, 1) -> 8 silo shards

    def flat(r):
        return np.concatenate([np.ravel(np.asarray(l))
                               for l in jax.tree.leaves(r.params)])

    for agg in ("fedavg", "fedprox", "fedsgd",
                "median", "trimmed_mean", "krum"):
        kw = dict(opt=adamw(1e-2), rounds=2, local_epochs=2, batch_size=8,
                  engine="scan", seed=3, aggregator=agg,
                  fedprox_mu=0.1 if agg == "fedprox" else 0.0)
        if agg in federated.ROBUST_AGGREGATORS:
            # hostile extras ride along: dropout + one scaled silo — the
            # robust sharded boundary must still match the vmap plan
            kw.update(dropout_rate=0.25,
                      silo_scale=[1.0, -3.0, 1.0, 1.0, 1.0])
        base = run_federated(loss, params, silos, **kw)
        sh = run_federated(loss, params, silos, mesh=mesh, **kw)
        rel = np.max(np.abs(flat(base) - flat(sh))) / (
            np.max(np.abs(flat(base))) + 1e-12)
        assert rel <= 1e-5, (agg, rel)
        print("AGREE", agg, rel)

    # collective structure: lower the sharded plan and census collectives
    # via repro.analysis (same regex the old inline counter used, so the
    # asserted counts are bit-identical). The rounds-scan body must hold
    # exactly one all-reduce per param leaf plus one for the loss, per
    # hierarchy level — and the count must not change with local_epochs (a
    # leak of collectives into the local phase would scale with E).
    batch_loss = federated._make_batch_loss(loss, True, 0.0)
    padded = pad_silo_data(silos, 8, min_silos=8)
    args = federated._plan_args(padded, 3, 2)

    def hist(epochs, aggregator):
        plan = federated.make_fl_plan(
            num_silos=padded.num_silos, num_batches=padded.num_batches,
            batch_size=padded.batch_size, opt=adamw(1e-2),
            batch_loss=batch_loss, rounds=2, local_epochs=epochs,
            aggregator=aggregator, masked=True, mesh=mesh)
        lowered = plan.lower(params, *args)
        # piggyback the privacy audit: no plan flavor may bake tenant data
        assert_no_baked_data(lowered, min_elems=512)
        return collective_census(lowered)

    leaves = len(jax.tree_util.tree_leaves(params))
    # weighted boundary: one all-reduce per leaf + one for the loss, no
    # other collective, invariant to local_epochs (a leak into the local
    # phase would scale with E)
    h1, h3 = hist(1, "fedavg"), hist(3, "fedavg")
    assert h1 == h3 == {"all-reduce": leaves + 1}, (h1, h3, leaves)
    # robust boundary: the psum becomes one all-gather per leaf plus one
    # for the availability mask; the loss all-reduce is the only reduce
    for agg in ("median", "trimmed_mean", "krum"):
        hr = hist(2, agg)
        assert hr == {"all-reduce": 1, "all-gather": leaves + 1}, (agg, hr)
    print("COLLECTIVES_OK", h1["all-reduce"])
""")


def test_sharded_8dev_agreement_and_collective_structure():
    r = subprocess.run([sys.executable, "-c", SHARDED_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:]
    for agg in ("fedavg", "fedprox", "fedsgd",
                "median", "trimmed_mean", "krum"):
        assert f"AGREE {agg}" in r.stdout, r.stdout
    assert "COLLECTIVES_OK" in r.stdout, r.stdout


MESH_VALIDATION_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=6"
    import jax
    from repro.launch.mesh import make_host_mesh

    assert jax.device_count() == 6
    m = make_host_mesh(model=2)             # data defaults to 6 // 2 = 3
    assert m.devices.shape == (3, 2), m.devices.shape
    try:
        make_host_mesh(model=4)             # 6 // 4 = 1 -> 1x4 over 6: valid
    except ValueError:
        raise SystemExit("model=4 with data=1 should fit on 6 devices")
    try:
        make_host_mesh(model=4, data=2)     # 8 > 6 devices
        raise SystemExit("data=2 model=4 should have raised")
    except ValueError as e:
        assert "6" in str(e) and "8" in str(e), e
        print("RAISES_WITH_COUNT")
    try:
        make_host_mesh(model=7)             # more model shards than devices
        raise SystemExit("model=7 should have raised")
    except ValueError as e:
        assert "6" in str(e), e
        print("MODEL_TOO_BIG_OK")
""")


def test_make_host_mesh_validation_names_device_count():
    """Satellite: the old `data * model <= n` assert admitted shapes that
    only failed later inside mesh consumers; now invalid shapes raise
    immediately, naming the available device count."""
    r = subprocess.run([sys.executable, "-c", MESH_VALIDATION_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"},
                       cwd="/root/repo")
    assert r.returncode == 0, r.stderr[-3000:] or r.stdout
    assert "RAISES_WITH_COUNT" in r.stdout, r.stdout
    assert "MODEL_TOO_BIG_OK" in r.stdout, r.stdout


# --------------------------------------------------------------------------
# bounded-memory eval: rounds ≫ eval_chunk streams, never stacks
# --------------------------------------------------------------------------

def test_rounds_200_streamed_eval_smoke():
    """A rounds=200 run with eval enabled — the config class the old
    (rounds, |params|) stack made impossible — completes in chunked
    dispatches and reports one history record per round."""
    silos = _linear_silos([12, 10], seed=4)
    params = _params(seed=5)
    calls = []
    ev = lambda p: {"w0": float(np.asarray(
        jax.tree_util.tree_leaves(p)[0]).ravel()[0])}
    res = run_federated(_reg_loss, params, silos, opt=adamw(1e-2), rounds=200,
                        local_epochs=1, batch_size=8, engine="scan", seed=6,
                        eval_fn=lambda p: (calls.append(1), ev(p))[1],
                        eval_chunk=16)
    assert len(res.history) == 200 and len(calls) == 200
    assert all(np.isfinite(h["loss"]) and np.isfinite(h["w0"])
               for h in res.history)
    # params evolve across the stream (the carry really advances)
    assert res.history[0]["w0"] != res.history[-1]["w0"]
