"""Experiment II (paper Fig. 5, Table 3): all six datasets × five methods,
d=5 groups × c=4 users (paper layout). Claim under test: FedDCL ≫ Local and
comparable to FedAvg / DC on every dataset."""
from __future__ import annotations

import json
import os

from benchmarks.common import run_all_methods

DATASETS = ["battery_small", "credit_rating", "eicu", "human_activity",
            "mnist", "fashion_mnist"]


def run(fast: bool = False, datasets=None):
    datasets = datasets or (DATASETS[:3] if fast else DATASETS)
    all_res = {}
    for name in datasets:
        n_ij = 1000 if name == "fashion_mnist" and not fast else 100
        res = run_all_methods(
            name, d=5, c=4, n_ij=n_ij,
            rounds=5 if fast else 20, local_epochs=2 if fast else 4,
            epochs=10 if fast else 40,
            n_test=500 if fast else 1000)
        all_res[name] = res
        m = res["metrics"]
        unit = "RMSE" if res["task"] == "regression" else "acc"
        print(f"{name:16s} ({unit}): " + "  ".join(
            f"{k}={v:.4f}" for k, v in m.items()))
    os.makedirs("results", exist_ok=True)
    with open("results/exp2_datasets.json", "w") as f:
        json.dump({k: {"metrics": v["metrics"], "task": v["task"]}
                   for k, v in all_res.items()}, f, indent=1)
    return all_res


if __name__ == "__main__":
    run()
