"""Kernel micro-benchmarks: wall time of the jnp reference paths on CPU
(the Pallas kernels target TPU; interpret mode is a correctness harness, not
a perf path — noted in the CSV as 'interpret')."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def _time(fn, *args, iters: int = 5, repeats: int = 3) -> float:
    """Best-of-`repeats` mean over `iters` calls — the min filters out CPU
    scheduling noise that would otherwise swamp sub-ms kernels."""
    out = fn(*args)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        for _ in range(iters):
            out = fn(*args)
        jax.block_until_ready(out)
        best = min(best, (time.perf_counter() - t0) / iters * 1e6)   # us
    return best


def run(fast: bool = False):
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 5)

    from repro.kernels.flash_attention import ops as fa
    B, S, H, KV, hd = 1, 512 if fast else 1024, 8, 4, 64
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, KV, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, KV, hd), jnp.float32)
    us = _time(lambda a, b, c: fa.flash_attention(a, b, c, backend="ref"), q, k, v)
    flops = 4 * B * S * S * H * hd
    rows.append(("flash_attention_ref_xla", us, f"{flops/us/1e3:.1f}GFLOP/s"))

    from repro.kernels.gram import ops as gr
    a = jax.random.normal(ks[3], (2000, 256), jnp.float32)
    us = _time(lambda x: gr.gram(x, backend="ref"), a)
    rows.append(("gram_ref_xla", us, f"{2*2000*256*256/us/1e3:.1f}GFLOP/s"))

    # batched collaboration engine vs the legacy per-group Python loop
    # (d groups of stacked anchor representations, protocol step 3a sizes)
    d, r, m = 16, 2000, 32
    ab = jax.random.normal(ks[3], (d, r, m), jnp.float32)
    us_loop = _time(
        lambda x: [gr.gram(x[i], backend="ref") for i in range(d)], ab,
        iters=10)
    us_bat = _time(lambda x: gr.gram_batched(x, backend="ref"), ab, iters=10)
    rows.append(("gram_group_loop_d16", us_loop, f"{d}x dispatch"))
    rows.append(("gram_batched_d16", us_bat,
                 f"speedup={us_loop/max(us_bat,1e-9):.1f}x"))

    from repro.kernels.rwkv6 import ops as rw
    B, S, Hh, K = 1, 256 if fast else 1024, 4, 64
    r = jax.random.normal(ks[0], (B, S, Hh, K))
    kk = jax.random.normal(ks[1], (B, S, Hh, K))
    vv = jax.random.normal(ks[2], (B, S, Hh, K))
    lw = -jnp.exp(jnp.clip(jax.random.normal(ks[3], (B, S, Hh, K)), -8, 1.6))
    u = jax.random.normal(ks[4], (Hh, K)) * 0.3
    us_scan = _time(lambda *x: rw.wkv6(*x, backend="scan"), r, kk, vv, lw, u,
                    iters=2, repeats=1)
    us_chunk = _time(lambda *x: rw.wkv6(*x, backend="chunked"), r, kk, vv, lw,
                     u, iters=2, repeats=1)
    rows.append(("wkv6_scan_oracle", us_scan, "sequential"))
    rows.append(("wkv6_chunked_xla", us_chunk,
                 f"speedup={us_scan/max(us_chunk,1e-9):.1f}x"))
    return rows


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
