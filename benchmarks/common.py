"""Shared harness for the paper-experiment benchmarks: run all five methods
(Centralized / Local / FedAvg / DC / FedDCL) on one dataset layout."""
from __future__ import annotations

import time
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.feddcl_mlp import PAPER_MLPS
from repro.core import baselines, protocol
from repro.core.federated import run_federated
from repro.data.partition import split_dirichlet, split_iid
from repro.data.tabular import make_dataset, train_test_split
from repro.models import mlp
from repro.optim import adamw


def run_all_methods(dataset: str, *, d: int = 5, c: int = 4, n_ij: int = 100,
                    rounds: int = 20, local_epochs: int = 4, epochs: int = 40,
                    n_test: int = 1000, seed: int = 0, lr: float = 1e-3,
                    non_iid: bool = False, dirichlet_alpha: float = 0.5,
                    methods=None, track_rounds: bool = False,
                    engine: str = "host", svd_backend: str = "host",
                    cache: bool = False) -> Dict:
    """Returns {"metrics": {method: test metric}, "curves": {...}, "task": str}.
    Paper setup: batch 32; Centralized/Local/DC train `epochs`; FedAvg/FedDCL
    run `rounds` rounds × `local_epochs` epochs (§4.1).

    All five methods train through the ONE federated engine
    (core/federated.py): `engine` selects the per-batch-dispatch host loop
    or the fully compiled lax.scan program; `svd_backend` selects the step-3
    collaboration backend for FedDCL (DESIGN.md §3). cache=True (scan engine
    only) routes every method through the shared compiled-plan cache with
    stable loss/optimizer identities, so grid drivers (experiments/sweep.py,
    exp3_groups) reuse executables across configs instead of recompiling."""
    cfg = PAPER_MLPS[dataset]
    methods = methods or ["Centralized", "Local", "FedAvg", "DC", "FedDCL"]
    n_train = d * c * n_ij
    ds = make_dataset(dataset, n=n_train + n_test + 200, seed=seed)
    (Xtr, Ytr), (Xte, Yte) = train_test_split(ds, n_train, n_test, seed=seed)
    if non_iid:
        Xs, Ys = split_dirichlet(Xtr, Ytr, d, [c] * d, n_ij,
                                 alpha=dirichlet_alpha, seed=seed)
    else:
        Xs, Ys = split_iid(Xtr, Ytr, d, [c] * d, n_ij, seed=seed)
    task = cfg.task
    key = jax.random.PRNGKey(seed)
    loss = lambda p, x, y: mlp.mlp_per_example_loss(p, x, y, task)
    opt = adamw(lr)
    cache_kw = (dict(cache=True, loss_id=("mlp_per_example_loss", task),
                     opt_id=("adamw", lr))
                if cache and engine == "scan" else {})
    Xte_j, Yte_j = jnp.asarray(Xte), jnp.asarray(Yte)

    def metric(p, X=Xte_j):
        return mlp.mlp_metric(p, X, Yte_j, task)

    out: Dict[str, float] = {}
    curves: Dict[str, List[float]] = {}
    times: Dict[str, float] = {}

    for method in methods:
        t0 = time.perf_counter()
        if method == "Centralized":
            p = mlp.for_config(key, cfg, reduced=False)
            ev = (lambda pp: {"metric": metric(pp)}) if track_rounds else None
            p, hist = baselines.sgd_train(loss, p, Xtr, Ytr, opt=opt,
                                          epochs=epochs, eval_fn=ev,
                                          engine=engine, **cache_kw)
            out[method] = metric(p)
            if track_rounds:
                curves[method] = [h["metric"] for h in hist]
        elif method == "Local":
            p = mlp.for_config(key, cfg, reduced=False)
            ev = (lambda pp: {"metric": metric(pp)}) if track_rounds else None
            p, hist = baselines.sgd_train(loss, p, Xs[0][0], Ys[0][0],
                                          opt=opt, epochs=epochs,
                                          eval_fn=ev, engine=engine,
                                          **cache_kw)
            out[method] = metric(p)
            if track_rounds:
                curves[method] = [h["metric"] for h in hist]
        elif method == "FedAvg":
            p = mlp.for_config(key, cfg, reduced=False)
            flat = [(Xs[i][j], Ys[i][j]) for i in range(d) for j in range(c)]
            ev = (lambda pp: {"metric": metric(pp)}) if track_rounds else None
            res = run_federated(loss, p, flat, opt=opt, rounds=rounds,
                                local_epochs=local_epochs, eval_fn=ev,
                                engine=engine, **cache_kw)
            out[method] = metric(res.params)
            if track_rounds:
                curves[method] = [h["metric"] for h in res.history]
        elif method == "DC":
            flatX = [Xs[i][j] for i in range(d) for j in range(c)]
            flatY = [Ys[i][j] for i in range(d) for j in range(c)]
            maps, Gs, collabX = baselines.dc_setup(
                flatX, m_tilde=cfg.reduced_dim, seed=seed)
            p = mlp.for_config(key, cfg, reduced=True)
            Xte_dc = jnp.asarray(np.asarray(maps[0](Xte) @ Gs[0]))
            ev = (lambda pp: {"metric": metric(pp, Xte_dc)}) if track_rounds else None
            p, hist = baselines.sgd_train(loss, p, np.concatenate(collabX),
                                          np.concatenate(flatY), opt=opt,
                                          epochs=epochs, eval_fn=ev,
                                          engine=engine, **cache_kw)
            out[method] = metric(p, Xte_dc)
            if track_rounds:
                curves[method] = [h["metric"] for h in hist]
        elif method == "FedDCL":
            setup = protocol.run_protocol(Xs, Ys, m_tilde=cfg.reduced_dim,
                                          anchor_r=2000, seed=seed,
                                          svd_backend=svd_backend)
            p = mlp.for_config(key, cfg, reduced=True)
            tr = setup.user_transform(0, 0)
            Xte_f = jnp.asarray(np.asarray(tr(Xte)))
            ev = (lambda pp: {"metric": metric(pp, Xte_f)}) if track_rounds else None
            res = run_federated(loss, p, setup.fed_silos(),
                                opt=opt, rounds=rounds,
                                local_epochs=local_epochs, eval_fn=ev,
                                engine=engine, **cache_kw)
            out[method] = metric(res.params, Xte_f)
            if track_rounds:
                curves[method] = [h["metric"] for h in res.history]
        times[method] = time.perf_counter() - t0

    return {"metrics": out, "curves": curves, "task": task, "times": times}
