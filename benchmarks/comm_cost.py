"""Communication-cost accounting (the paper's §3.2 claim and the systems
point of the whole method): per-user cross-institution round trips and bytes,
FedDCL vs FedAvg, plus the mesh-level per-step collective amortization
(cross-silo bytes / H) read from the dry-run JSONs when present."""
from __future__ import annotations

import glob
import json
import os

import numpy as np

from repro.configs.feddcl_mlp import PAPER_MLPS
from repro.core import protocol
from repro.data.partition import split_iid
from repro.data.tabular import make_dataset, train_test_split
from repro.models import mlp

import jax


def protocol_comm(dataset: str = "mnist", d: int = 5, c: int = 4,
                  n_ij: int = 100, rounds: int = 20):
    cfg = PAPER_MLPS[dataset]
    ds = make_dataset(dataset, n=d * c * n_ij + 100, seed=0)
    (Xtr, Ytr), _ = train_test_split(ds, d * c * n_ij, 64, seed=0)
    Xs, Ys = split_iid(Xtr, Ytr, d, [c] * d, n_ij, seed=0)
    setup = protocol.run_protocol(Xs, Ys, m_tilde=cfg.reduced_dim, seed=0)
    params = mlp.for_config(jax.random.PRNGKey(0), cfg, reduced=True)
    pbytes = sum(np.prod(l.shape) * 4 for l in jax.tree_util.tree_leaves(params))
    protocol.finalize_user_models(setup, h=lambda z: z,
                                  h_params_bytes=int(pbytes))

    trips = setup.comm.user_round_trips()
    user_bytes = setup.comm.total_bytes(
        lambda e: e.src.startswith("user") or e.dst.startswith("user"))
    # FedAvg: every user exchanges model params twice per round
    fedavg_user_msgs = 2 * rounds
    fedavg_user_bytes = int(2 * rounds * pbytes * d * c)
    feddcl_server_bytes = setup.comm.total_bytes(
        lambda e: not (e.src.startswith("user") or e.dst.startswith("user")))
    # DC-server <-> FL-server federated phase (rounds × params × d × 2)
    feddcl_server_bytes += int(2 * rounds * pbytes * d)

    rows = {
        "users": d * c,
        "feddcl_msgs_per_user": max(trips.values()),
        "fedavg_msgs_per_user": fedavg_user_msgs,
        "feddcl_user_bytes_total": user_bytes,
        "fedavg_user_bytes_total": fedavg_user_bytes,
        "feddcl_server_bytes_total": int(feddcl_server_bytes),
        "model_bytes": int(pbytes),
    }
    return rows


def mesh_amortization(result_dir: str = "results/dryrun", H: int = 4):
    """Per-step cross-silo collective bytes: baseline vs feddcl local+sync/H."""
    out = {}
    for f in glob.glob(os.path.join(result_dir, "*__train_4k__16x16__*.json")):
        rec = json.load(open(f))
        key = (rec["arch"], rec["mode"])
        out[key] = rec["collective_bytes_per_device"]
    rows = {}
    for (arch, mode), v in sorted(out.items()):
        rows.setdefault(arch, {})[mode] = v
    table = []
    for arch, modes in rows.items():
        if "feddcl" in modes and "feddcl_sync" in modes and "baseline" in modes:
            amort = modes["feddcl"] + modes["feddcl_sync"] / H
            table.append({
                "arch": arch,
                "baseline_coll_bytes_per_step": modes["baseline"],
                "feddcl_amortized_coll_bytes_per_step": amort,
                "reduction_x": modes["baseline"] / max(amort, 1.0),
            })
    return table


def run(fast: bool = False):
    rows = protocol_comm()
    print("Protocol communication (mnist stand-in, d=5, c=4, 20 FL rounds):")
    for k, v in rows.items():
        print(f"  {k:32s} {v:,}")
    ratio = rows["fedavg_user_bytes_total"] / max(rows["feddcl_user_bytes_total"], 1)
    print(f"  user-traffic reduction vs FedAvg: {ratio:.1f}x, "
          f"msgs {rows['fedavg_msgs_per_user']} -> {rows['feddcl_msgs_per_user']}")
    table = mesh_amortization()
    if table:
        print("\nMesh-level per-step cross-silo bytes (dry-run):")
        for r in table:
            print(f"  {r['arch']:24s} baseline={r['baseline_coll_bytes_per_step']:.3e} "
                  f"feddcl(H=4)={r['feddcl_amortized_coll_bytes_per_step']:.3e} "
                  f"({r['reduction_x']:.2f}x)")
    os.makedirs("results", exist_ok=True)
    with open("results/comm_cost.json", "w") as f:
        json.dump({"protocol": rows, "mesh": table}, f, indent=1)
    return rows, table


if __name__ == "__main__":
    run()
