"""Federated-engine benchmark: the compiled scan engine vs the per-batch
dispatch host loop (core/federated.py, DESIGN.md §5) across silo counts and
round budgets — the FL-phase analogue of kernels_bench's batched-Gram row.

For each (d, rounds) case both engines train the same MLP on the same
ragged silo stack with the same seed/schedule; we record host dispatch time
(marginal cost of the FL rounds with the per-call step jit cancelled out),
host total time (one call incl. its unavoidable re-jit), scan cold time
(trace + compile + run: what a one-shot caller pays), scan warm time (the
compiled FL phase re-invoked), and the host/scan parameter agreement.
Speedup_warm = host dispatch / scan warm (steady state); speedup_cold =
host total / scan cold (one-shot).

  PYTHONPATH=src python benchmarks/fed_bench.py [--fast] [--out PATH]

Writes results/BENCH_fed.json (cited in DESIGN.md / ROADMAP.md).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import federated
from repro.core.federated import (make_scan_runner, pad_silo_data,
                                  run_federated)
from repro.models import mlp
from repro.optim import adamw

M_FEAT = 16
LOCAL_EPOCHS = 4
BATCH = 32


def _make_silos(d: int, seed: int = 0):
    """d ragged silos (84..116 samples) of a linear-regression task."""
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((M_FEAT, 1))
    silos = []
    for i in range(d):
        n = 84 + 8 * (i % 5)
        r = np.random.default_rng(seed * 1009 + i)
        X = r.standard_normal((n, M_FEAT))
        silos.append((X, X @ w + 0.01 * r.standard_normal((n, 1))))
    return silos


def _rel_diff(a, b) -> float:
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    return max(
        float(np.max(np.abs(np.asarray(x) - np.asarray(y))) /
              (np.max(np.abs(np.asarray(x))) + 1e-12))
        for x, y in zip(la, lb))


def bench_case(d: int, rounds: int, *, warm_iters: int = 3) -> Dict:
    silos = _make_silos(d)
    params = mlp.init_mlp_params(jax.random.PRNGKey(0), M_FEAT, (32,), 1)
    loss = lambda p, x, y: mlp.mlp_per_example_loss(p, x, y, "regression")
    kw = dict(opt=adamw(1e-3), rounds=rounds, local_epochs=LOCAL_EPOCHS,
              batch_size=BATCH, seed=0)

    # The host engine re-jits its step closure on every run_federated call
    # (jit caches key on function identity), so a single wall-clock includes
    # one unavoidable trace+compile. Report both: t_host_total (what one
    # call costs) and t_host_dispatch = t(3R) − t(R) over 2R rounds, where
    # the compile cancels and only marginal per-batch dispatch remains —
    # the steady-state number speedup_warm is computed from. Each leg is
    # best-of-3 because compile-time jitter (~±0.3 s) would otherwise swamp
    # the small-R dispatch signal.
    def _host_time(r):
        best, res = float("inf"), None
        for _ in range(3):
            t0 = time.perf_counter()
            out = run_federated(loss, params, silos, engine="host",
                                **{**kw, "rounds": r})
            dt = time.perf_counter() - t0
            if dt < best:
                best, res = dt, out
        return best, res

    t_host_total, host = _host_time(rounds)
    t_3r, _ = _host_time(3 * rounds)
    t_host = max((t_3r - t_host_total) / 2.0, 1e-4)

    t0 = time.perf_counter()
    scan = run_federated(loss, params, silos, engine="scan", **kw)
    t_cold = time.perf_counter() - t0

    # warm: the SAME compiled runner re-invoked (executable cache hit)
    padded = pad_silo_data(silos, BATCH)
    batch_loss = federated._make_batch_loss(loss, True, 0.0)
    runner = make_scan_runner(batch_loss, padded, opt=adamw(1e-3),
                              rounds=rounds, local_epochs=LOCAL_EPOCHS, seed=0)
    jax.block_until_ready(runner(params))                 # compile
    t_warm = float("inf")
    for _ in range(warm_iters):
        t0 = time.perf_counter()
        jax.block_until_ready(runner(params))
        t_warm = min(t_warm, time.perf_counter() - t0)

    dispatches = d * rounds * LOCAL_EPOCHS * padded.num_batches
    return {
        "d": d, "rounds": rounds, "local_epochs": LOCAL_EPOCHS,
        "batch_size": BATCH, "host_step_dispatches": dispatches,
        "t_host_dispatch_s": round(t_host, 4),
        "t_host_total_s": round(t_host_total, 4),
        "t_scan_cold_s": round(t_cold, 4),
        "t_scan_warm_s": round(t_warm, 4),
        "speedup_warm": round(t_host / t_warm, 1),
        "speedup_cold": round(t_host_total / t_cold, 1),
        "rel_param_diff": _rel_diff(host.params, scan.params),
        "final_loss_host": host.history[-1]["loss"],
        "final_loss_scan": scan.history[-1]["loss"],
    }


# collective counting lives in repro.analysis.hlo_audit (DESIGN.md §9) —
# the one census implementation shared with the tests and feddcl_audit
from repro.analysis import collective_census as _collective_histogram  # noqa: E402,E501


def bench_sharded_case(d: int, rounds: int, *, warm_iters: int = 3,
                       aggregator: str = "fedavg") -> Dict:
    """One worker-process case: vmap (unsharded) plan vs the same plan
    sharded over a mesh spanning every available device, plus the
    round-boundary collective-structure check on the sharded HLO. The
    expected structure is aggregator-aware (DESIGN.md §8): weighted
    aggregators psum partial weighted sums; robust aggregators all_gather
    the silo submissions and reduce only the loss scalar."""
    from repro.launch.mesh import make_host_mesh

    silos = _make_silos(d)
    params = mlp.init_mlp_params(jax.random.PRNGKey(0), M_FEAT, (32,), 1)
    loss = lambda p, x, y: mlp.mlp_per_example_loss(p, x, y, "regression")
    batch_loss = federated._make_batch_loss(loss, True, 0.0)
    padded = pad_silo_data(silos, BATCH)
    args = federated._plan_args(padded, 0, rounds)
    devices = jax.device_count()

    def plan_for(mesh):
        return federated.make_fl_plan(
            num_silos=padded.num_silos, num_batches=padded.num_batches,
            batch_size=padded.batch_size, opt=adamw(1e-3),
            batch_loss=batch_loss, rounds=rounds, local_epochs=LOCAL_EPOCHS,
            aggregator=aggregator, masked=padded.has_padding, mesh=mesh)

    def warm_time(plan):
        out = jax.block_until_ready(plan(params, *args))     # compile
        t = float("inf")
        for _ in range(warm_iters):
            t0 = time.perf_counter()
            jax.block_until_ready(plan(params, *args))
            t = min(t, time.perf_counter() - t0)
        return t, out

    base = plan_for(None)
    t_vmap, (p_vmap, _) = warm_time(base)

    mesh = make_host_mesh(model=1)                  # ("data", "model")=(n, 1)
    sharded = plan_for(mesh)
    t_sharded, (p_sharded, _) = warm_time(sharded)
    hlo = sharded.lower(params, *args).compile().as_text()
    hist = _collective_histogram(hlo)
    n_leaves = len(jax.tree_util.tree_leaves(params))

    return {
        "devices": devices, "d": d, "rounds": rounds,
        "aggregator": aggregator,
        "local_epochs": LOCAL_EPOCHS, "batch_size": BATCH,
        "t_vmap_warm_s": round(t_vmap, 4),
        "t_sharded_warm_s": round(t_sharded, 4),
        "speedup_sharded": round(t_vmap / t_sharded, 2),
        "rel_param_diff": _rel_diff(p_vmap, p_sharded),
        "collectives": hist,
        "param_leaves": n_leaves,
    }


def run_sharded_parent(fast: bool, out_path: str) -> None:
    """Spawn one subprocess per virtual-device count (XLA_FLAGS must be set
    before jax initializes, hence processes, not threads), collect rows,
    assert the sharded-engine invariants, write BENCH_fed_sharded.json."""
    import subprocess
    import sys
    import tempfile

    base_cases = [(8, 5)] if fast else [(8, 5), (32, 5), (8, 20), (32, 20)]
    cases = [(d, r, "fedavg") for d, r in base_cases]
    # robust-boundary rows: the collective structure changes (all_gather
    # instead of psum), so each robust aggregator gets its own asserted row
    robust = ("median",) if fast else ("median", "trimmed_mean", "krum")
    cases += [(8, 5, agg) for agg in robust]
    rows: List[Dict] = []
    for devices in (1, 8):
        env = dict(os.environ)
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}")
        for d, rounds, agg in cases:
            with tempfile.NamedTemporaryFile(suffix=".json", delete=False) as f:
                tmp = f.name
            subprocess.run(
                [sys.executable, os.path.abspath(__file__),
                 "--sharded-worker", "--d", str(d), "--rounds", str(rounds),
                 "--aggregator", agg, "--out", tmp],
                env=env, check=True)
            with open(tmp) as f:
                row = json.load(f)
            os.unlink(tmp)
            rows.append(row)
            print(f"devices={devices} d={d:3d} rounds={rounds:3d} "
                  f"{agg:12s}  "
                  f"vmap {row['t_vmap_warm_s']:7.4f}s  "
                  f"sharded {row['t_sharded_warm_s']:7.4f}s  "
                  f"({row['speedup_sharded']:.2f}x)  "
                  f"agree {row['rel_param_diff']:.2e}  "
                  f"collectives {row['collectives']}")

    for row in rows:
        # Short-horizon rows get the acceptance tolerance. Long-horizon
        # (rounds=20) timing rows only a sanity bound: the sharded psum of
        # per-shard partial sums and the unsharded single tensordot sum in
        # different f32 orders, and adam amplifies that ~1e-7/round seed
        # chaotically over many rounds (observed non-monotonic ~1e-3 at 10
        # rounds, ~6e-4 at 20 — both trajectories converge to the same
        # optimum).
        tol = 1e-5 if row["rounds"] <= 5 else 1e-2
        assert row["rel_param_diff"] <= tol, row
        if row["devices"] > 1:
            if row["aggregator"] in federated.ROBUST_AGGREGATORS:
                # robust boundary: one all-gather per param leaf plus one
                # for the availability mask; the only all-reduce is the
                # per-round loss scalar (the robust statistic itself is
                # computed redundantly per shard on the gathered stack)
                assert row["collectives"] == {
                    "all-reduce": 1,
                    "all-gather": row["param_leaves"] + 1}, row
            else:
                # weighted boundary: round-boundary-only traffic — exactly
                # one all-reduce per param leaf plus one for the loss, per
                # hierarchy level (single-level host mesh here), and no
                # other collective kind anywhere in the module
                assert set(row["collectives"]) == {"all-reduce"}, row
                assert row["collectives"]["all-reduce"] == \
                    row["param_leaves"] + 1, row

    out = {
        "bench": "fed_engine_sharded_vs_vmap",
        "platform": jax.default_backend(),
        "jax": jax.__version__,
        "invariants": {
            "agreement_tol": "1e-5 at rounds<=5; 1e-2 sanity bound on the "
                             "rounds=20 timing rows (f32 reduction-order "
                             "seed amplified chaotically by adam)",
            "collectives": "weighted aggregators: all-reduce only, "
                           "(param_leaves + 1) per hierarchy level in the "
                           "round-scan body — round boundaries only, local "
                           "phase clean; robust aggregators: "
                           "(param_leaves + 1) all-gathers (params + "
                           "availability mask) + 1 loss all-reduce",
        },
        "cases": rows,
    }
    os.makedirs(os.path.dirname(out_path) or ".", exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(out, f, indent=1)
    print(f"-> {out_path}")


def run(fast: bool = False) -> List[Dict]:
    cases = ([(2, 5), (8, 5)] if fast
             else [(d, r) for d in (2, 8, 32) for r in (5, 20)])
    rows = []
    for d, rounds in cases:
        row = bench_case(d, rounds)
        rows.append(row)
        print(f"d={d:3d} rounds={rounds:3d}  host {row['t_host_dispatch_s']:8.3f}s "
              f"dispatch ({row['host_step_dispatches']} steps, "
              f"{row['t_host_total_s']:.3f}s incl. jit)  "
              f"scan cold {row['t_scan_cold_s']:7.3f}s  "
              f"warm {row['t_scan_warm_s']:7.4f}s  "
              f"speedup {row['speedup_warm']:6.1f}x (cold "
              f"{row['speedup_cold']:.1f}x)  "
              f"agree {row['rel_param_diff']:.2e}")
    return rows


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="CI smoke: d<=8, rounds=5 only")
    ap.add_argument("--out", default=None)
    ap.add_argument("--sharded", action="store_true",
                    help="sharded-vs-vmap rows at 1 and 8 virtual devices "
                         "(spawns worker subprocesses; writes "
                         "results/BENCH_fed_sharded.json)")
    ap.add_argument("--sharded-worker", action="store_true",
                    help=argparse.SUPPRESS)
    ap.add_argument("--d", type=int, default=8, help=argparse.SUPPRESS)
    ap.add_argument("--rounds", type=int, default=5, help=argparse.SUPPRESS)
    ap.add_argument("--aggregator", default="fedavg", help=argparse.SUPPRESS)
    args = ap.parse_args()

    if args.sharded_worker:
        row = bench_sharded_case(args.d, args.rounds,
                                 aggregator=args.aggregator)
        with open(args.out, "w") as f:
            json.dump(row, f)
        return
    if args.sharded:
        run_sharded_parent(args.fast,
                           args.out or "results/BENCH_fed_sharded.json")
        return

    args.out = args.out or "results/BENCH_fed.json"
    rows = run(fast=args.fast)
    out = {
        "bench": "fed_engine_scan_vs_host",
        "platform": jax.default_backend(),
        "jax": jax.__version__,
        "cases": rows,
    }
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(out, f, indent=1)
    print(f"-> {args.out}")


if __name__ == "__main__":
    main()
