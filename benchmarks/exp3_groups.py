"""Experiment III (paper Fig. 6): accuracy vs number of groups d for the
MNIST stand-in, c_i=4 users per group. Claim under test: FedDCL accuracy
increases with d (more total data), tracking Centralized/DC."""
from __future__ import annotations

import json
import os

from benchmarks.common import run_all_methods


def run(fast: bool = False):
    ds_grid = [1, 2, 4] if fast else [1, 2, 4, 6, 8, 10]
    out = {}
    for d in ds_grid:
        methods = ["Centralized", "DC", "FedDCL"] if d == 1 else \
            ["Centralized", "FedAvg", "DC", "FedDCL"]
        res = run_all_methods(
            "mnist", d=max(d, 1), c=4, n_ij=100,
            rounds=4 if fast else 15, local_epochs=2 if fast else 4,
            epochs=8 if fast else 30, n_test=500 if fast else 1000,
            methods=methods)
        out[d] = res["metrics"]
        print(f"d={d}: " + "  ".join(f"{k}={v:.4f}" for k, v in res["metrics"].items()))
    os.makedirs("results", exist_ok=True)
    with open("results/exp3_groups.json", "w") as f:
        json.dump(out, f, indent=1)
    feddcl = [out[d]["FedDCL"] for d in ds_grid]
    increasing = feddcl[-1] > feddcl[0]
    print(f"FedDCL acc d={ds_grid[0]} -> d={ds_grid[-1]}: "
          f"{feddcl[0]:.4f} -> {feddcl[-1]:.4f} (increasing={increasing})")
    return out


if __name__ == "__main__":
    run()
