"""Experiment III (paper Fig. 6): accuracy vs number of groups d for the
MNIST stand-in, c_i=4 users per group. Claim under test: FedDCL accuracy
increases with d (more total data), tracking Centralized/DC.

`scenarios()` additionally sweeps the batched collaboration engine over a
scenario matrix — d ∈ {2..32} groups × c ∈ {1..8} users/group × IID vs
Dirichlet non-IID — timing protocol step 3 on the "host" (serial NumPy)
and "device" (batched jitted) backends and recording their agreement, so
the batched-engine speedup is measured, not asserted.
"""
from __future__ import annotations

import json
import os
import time

import numpy as np

from benchmarks.common import run_all_methods


def run(fast: bool = False, engine: str = "host", cache: bool = False):
    """The d-grid rides the generic sweep loop (experiments/sweep.run_sweep)
    instead of an ad-hoc for-loop; engine="scan", cache=True additionally
    share compiled FL executables across the grid via the plan cache."""
    from experiments.sweep import run_sweep

    ds_grid = [1, 2, 4] if fast else [1, 2, 4, 6, 8, 10]

    def one_d(case):
        d = case["d"]
        methods = ["Centralized", "DC", "FedDCL"] if d == 1 else \
            ["Centralized", "FedAvg", "DC", "FedDCL"]
        res = run_all_methods(
            "mnist", d=max(d, 1), c=4, n_ij=100,
            rounds=4 if fast else 15, local_epochs=2 if fast else 4,
            epochs=8 if fast else 30, n_test=500 if fast else 1000,
            methods=methods, engine=engine, cache=cache)
        print(f"d={d}: " + "  ".join(f"{k}={v:.4f}"
                                     for k, v in res["metrics"].items()))
        return res["metrics"]

    rows = run_sweep([{"d": d} for d in ds_grid], one_d, label="exp3",
                     verbose=False)
    out = {r["d"]: {k: v for k, v in r.items() if k not in ("d", "time_s")}
           for r in rows}
    os.makedirs("results", exist_ok=True)
    with open("results/exp3_groups.json", "w") as f:
        json.dump(out, f, indent=1)
    feddcl = [out[d]["FedDCL"] for d in ds_grid]
    increasing = feddcl[-1] > feddcl[0]
    print(f"FedDCL acc d={ds_grid[0]} -> d={ds_grid[-1]}: "
          f"{feddcl[0]:.4f} -> {feddcl[-1]:.4f} (increasing={increasing})")
    return out


def scenarios(fast: bool = False, seed: int = 0):
    """Backend scenario matrix: setup (steps 1–3) wall time, host vs device,
    and the relative Frobenius disagreement of the collab representations."""
    from repro.core.protocol import run_protocol
    from repro.data.partition import split_dirichlet, split_iid

    d_grid = [2, 4, 8] if fast else [2, 4, 8, 16, 32]
    c_grid = [1, 4] if fast else [1, 2, 4, 8]
    parts = ["iid", "dirichlet"]
    m, m_tilde, n_ij, anchor_r = 32, 8, 50, 1000
    rng = np.random.default_rng(seed)
    rows = []
    for d in d_grid:
        for c in c_grid:
            n = d * c * n_ij
            X = rng.standard_normal((n + 64, m))
            Y = rng.integers(0, 5, size=n + 64).astype(np.float64)
            for part in parts:
                split = split_iid if part == "iid" else split_dirichlet
                Xs, Ys = split(X, Y, d, [c] * d, n_ij, seed=seed)
                res = {"d": d, "c": c, "partition": part}
                setups = {}
                for backend in ("host", "device"):
                    if backend == "device":   # absorb one-time jit compile
                        run_protocol(Xs, Ys, m_tilde=m_tilde,
                                     anchor_r=anchor_r, seed=seed,
                                     svd_backend=backend)
                    t0 = time.perf_counter()
                    setups[backend] = run_protocol(
                        Xs, Ys, m_tilde=m_tilde, anchor_r=anchor_r,
                        seed=seed, svd_backend=backend)
                    res[f"{backend}_s"] = time.perf_counter() - t0
                rel = max(
                    float(np.linalg.norm(a - b) / max(np.linalg.norm(a), 1e-12))
                    for a, b in zip(setups["host"].collab_X,
                                    setups["device"].collab_X))
                res["rel_frobenius"] = rel
                res["speedup"] = res["host_s"] / max(res["device_s"], 1e-12)
                rows.append(res)
                print(f"d={d:<3} c={c} {part:<9} host={res['host_s']:.3f}s "
                      f"device={res['device_s']:.3f}s "
                      f"speedup={res['speedup']:.2f}x rel={rel:.2e}")
    os.makedirs("results", exist_ok=True)
    with open("results/exp3_scenarios.json", "w") as f:
        json.dump(rows, f, indent=1)
    return rows


if __name__ == "__main__":
    import sys
    if "--scenarios" in sys.argv:
        scenarios(fast="--fast" in sys.argv)
    else:
        run(fast="--fast" in sys.argv,
            engine="scan" if "--engine=scan" in sys.argv else "host",
            cache="--cache" in sys.argv)
