"""Experiment I (paper Fig. 4, Tables 1–2): proof-of-concept on the
BatterySmall stand-in — 4 users in 2 groups, convergence per round of all
five methods. Claim under test: FedDCL converges at least as fast per round
as FedAvg and reaches comparable final RMSE.

`--engine` selects the federated trainer (core/federated.py): "host" is the
per-batch-dispatch reference loop, "scan" compiles the whole FL phase into
one program — same schedule, same results, far fewer dispatches
(benchmarks/fed_bench.py measures the gap).
"""
from __future__ import annotations

import argparse
import json
import os

from benchmarks.common import run_all_methods


def run(fast: bool = False, engine: str = "host", svd_backend: str = "host"):
    res = run_all_methods(
        "battery_small", d=2, c=2, n_ij=100,
        rounds=6 if fast else 20, local_epochs=4,
        epochs=12 if fast else 40, n_test=1000, track_rounds=True,
        engine=engine, svd_backend=svd_backend)
    os.makedirs("results", exist_ok=True)
    with open("results/exp1_convergence.json", "w") as f:
        json.dump(res, f, indent=1)
    m = res["metrics"]
    print(f"Exp I — BatterySmall RMSE (lower better), engine={engine}:")
    for k, v in m.items():
        print(f"  {k:12s} {v:.4f}")
    claims = {
        "feddcl_beats_local": m["FedDCL"] < m["Local"],
        "feddcl_comparable_fedavg": m["FedDCL"] < 1.5 * m["FedAvg"],
        "feddcl_comparable_dc": m["FedDCL"] < 1.5 * m["DC"],
    }
    print("claims:", claims)
    return res, claims


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true")
    ap.add_argument("--engine", default="host", choices=["host", "scan"])
    ap.add_argument("--svd-backend", default="host",
                    choices=["host", "device"])
    args = ap.parse_args()
    run(fast=args.fast, engine=args.engine, svd_backend=args.svd_backend)
