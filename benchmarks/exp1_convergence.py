"""Experiment I (paper Fig. 4, Tables 1–2): proof-of-concept on the
BatterySmall stand-in — 4 users in 2 groups, convergence per round of all
five methods. Claim under test: FedDCL converges at least as fast per round
as FedAvg and reaches comparable final RMSE."""
from __future__ import annotations

import json
import os

from benchmarks.common import run_all_methods


def run(fast: bool = False):
    res = run_all_methods(
        "battery_small", d=2, c=2, n_ij=100,
        rounds=6 if fast else 20, local_epochs=4,
        epochs=12 if fast else 40, n_test=1000, track_rounds=True)
    os.makedirs("results", exist_ok=True)
    with open("results/exp1_convergence.json", "w") as f:
        json.dump(res, f, indent=1)
    m = res["metrics"]
    print("Exp I — BatterySmall RMSE (lower better):")
    for k, v in m.items():
        print(f"  {k:12s} {v:.4f}")
    claims = {
        "feddcl_beats_local": m["FedDCL"] < m["Local"],
        "feddcl_comparable_fedavg": m["FedDCL"] < 1.5 * m["FedAvg"],
        "feddcl_comparable_dc": m["FedDCL"] < 1.5 * m["DC"],
    }
    print("claims:", claims)
    return res, claims


if __name__ == "__main__":
    run()
