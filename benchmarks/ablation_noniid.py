"""BEYOND-PAPER ablation: non-IID robustness (the paper defers this to
future work, §5). FedDCL vs FedAvg vs DC under Dirichlet label skew on the
human_activity stand-in.

Mechanistic expectation: FedDCL's alignment step is computed from the SHARED
anchor (distribution-independent), so the collaboration representation
quality should degrade less with skew than FedAvg's averaged weights
(client drift)."""
from __future__ import annotations

import json
import os

from benchmarks.common import run_all_methods


def run(fast: bool = False):
    out = {}
    grid = [("iid", False, None), ("dir0.5", True, 0.5), ("dir0.1", True, 0.1)]
    for name, non_iid, alpha in grid:
        kw = dict(d=4, c=3, n_ij=100,
                  rounds=5 if fast else 15, local_epochs=2 if fast else 4,
                  epochs=10 if fast else 30, n_test=500 if fast else 1000,
                  methods=["Local", "FedAvg", "DC", "FedDCL"])
        res = run_all_methods("human_activity", non_iid=non_iid,
                              dirichlet_alpha=alpha or 0.5, **kw)
        out[name] = res["metrics"]
        print(f"{name:8s}: " + "  ".join(f"{k}={v:.4f}"
                                         for k, v in res["metrics"].items()))
    os.makedirs("results", exist_ok=True)
    with open("results/ablation_noniid.json", "w") as f:
        json.dump(out, f, indent=1)
    return out


if __name__ == "__main__":
    run()
