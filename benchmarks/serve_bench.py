"""Collaboration-serving benchmark: warm vs cold bucketed dispatch, and
incremental onboarding vs a from-scratch protocol recompute (DESIGN.md §10).

Measures, for a mixed multi-tenant request stream on a `ServeCollab`
server:

  * cold sweep — first traffic of each shape bucket (pays trace+compile),
  * warm sweep — the same traffic pattern re-submitted: the acceptance bar
    is EXACTLY 0 executable builds (CompileCounter across the sweep) and
    p50/p99 request latency + rows/s at steady state,
  * artifact hygiene — assert_no_baked_data on every group's lowered
    resident step (tenant tables are runtime arguments, never constants),
  * onboarding — admitting new users onto the LIVE server (blocked-Gram +
    cached-factor update, tables refreshed) timed against the full
    `run_protocol` recompute of the grown deployment on the same anchor;
    asserts agreement <= 1e-5 and an incremental speedup >= 5x.

  PYTHONPATH=src python benchmarks/serve_bench.py [--fast] [--out PATH]

Writes results/BENCH_serve.json (cited in DESIGN.md / ROADMAP.md).
"""
from __future__ import annotations

import argparse
import json
import os
import time
from typing import Dict, List

import jax
import numpy as np

from repro.analysis.hlo_audit import CompileCounter, assert_no_baked_data
from repro.core import protocol
from repro.models import mlp
from repro.serve_collab import ServeCollab

M_RAW = 20
M_TILDE = 16
ONBOARD_SPEEDUP_BAR = 5.0
ONBOARD_AGREE_BAR = 1e-5


def _make_data(rng, d: int, c: int, n_ij: int):
    Xs = [[rng.standard_normal((n_ij, M_RAW)) for _ in range(c)]
          for _ in range(d)]
    Ys = [[rng.standard_normal((n_ij, 1)) for _ in range(c)] for _ in range(d)]
    return Xs, Ys


def _sweep(srv, rng, d: int, c: int, n_req: int, max_rows: int):
    """Submit a mixed-tenant stream and drain it; returns (dt, stats)."""
    for _ in range(n_req):
        g = int(rng.integers(0, d))
        u = int(rng.integers(0, c))
        srv.submit(rng.standard_normal(
            (int(rng.integers(1, max_rows + 1)), M_RAW)), g, u)
    t0 = time.perf_counter()
    out = srv.serve()
    dt = time.perf_counter() - t0
    assert all(s == "done" for s in out.status.values())
    return dt, srv.stats()


def _setup_agreement(inc, ref) -> float:
    """Max relative difference between an incrementally-grown setup and a
    from-scratch reference over Z, every G, every X̂."""
    worst = 0.0

    def rel(a, b):
        a, b = np.asarray(a), np.asarray(b)
        return float(np.abs(a - b).max() / max(1.0, np.abs(b).max()))

    worst = max(worst, rel(inc.Z, ref.Z))
    for i in range(ref.num_groups):
        for j in range(ref.num_users(i)):
            worst = max(worst, rel(inc.Gs[i][j], ref.Gs[i][j]))
        worst = max(worst, rel(inc.collab_X[i], ref.collab_X[i]))
    return worst


def run(fast: bool = False) -> Dict:
    # layout sized so the speedup claim is honest: the incremental path's
    # floor is the shared central refresh (Z + all-group G re-solve), so
    # tiny layouts where THAT dominates both sides can't separate them —
    # at these sizes the from-scratch per-user step-2/3 work (mapping SVDs,
    # full Grams, full QRs) dominates the recompute and the gap is real
    d, c = (4, 10) if fast else (5, 10)
    n_ij = 120 if fast else 200
    n_req = 24 if fast else 96
    max_rows = 24 if fast else 48
    anchor_r = 1024 if fast else 2048
    n_onboard = 2 if fast else 3
    rng = np.random.default_rng(0)

    Xs, Ys = _make_data(rng, d, c, n_ij)
    t0 = time.perf_counter()
    setup = protocol.run_protocol(Xs, Ys, m_tilde=M_TILDE, anchor_r=anchor_r,
                                  seed=0, onboard=True)
    t_setup = time.perf_counter() - t0
    params = mlp.init_mlp_params(jax.random.PRNGKey(0), setup.m_hat,
                                 (32,), 1)
    srv = ServeCollab.from_setup(setup, params, max_batch=64)

    # -- cold then warm sweep (identical traffic distribution) ------------
    # identical traffic both times (same stream seed): the cold pass pays
    # every bucket's trace+compile, the warm replay is pure steady state —
    # tail-batch pow2 buckets are traffic-dependent, so a different stream
    # could legitimately compile a fresh (unseen) tail width
    with CompileCounter() as cc_cold:
        t_cold, st_cold = _sweep(srv, np.random.default_rng(1), d, c, n_req,
                                 max_rows)
    srv.latencies.clear()
    with CompileCounter() as cc_warm:
        t_warm, st = _sweep(srv, np.random.default_rng(1), d, c, n_req,
                            max_rows)
    warm_rows = st["rows_served"] - st_cold["rows_served"]
    assert cc_warm.count == 0, \
        f"warm mixed-tenant sweep built {cc_warm.count} executables"

    # -- artifact hygiene: no tenant data baked into any group's step -----
    for g in range(setup.num_groups):
        assert_no_baked_data(srv.lower_step(g, 64))

    # -- onboarding: live incremental admit vs full protocol recompute ----
    grown_X = [list(row) for row in Xs]
    grown_Y = [list(row) for row in Ys]
    t_onboards: List[float] = []
    for k in range(n_onboard):
        Xn = rng.standard_normal((n_ij, M_RAW))
        Yn = rng.standard_normal((n_ij, 1))
        tgt = k % d
        t0 = time.perf_counter()
        srv.onboard_user(tgt, Xn, Yn)           # incl. table refresh
        t_onboards.append(time.perf_counter() - t0)
        grown_X[tgt].append(Xn)
        grown_Y[tgt].append(Yn)
    t_onboard = min(t_onboards)

    t_recompute = float("inf")
    ref = None
    for _ in range(3):
        t0 = time.perf_counter()
        ref = protocol.run_protocol(grown_X, grown_Y, m_tilde=M_TILDE,
                                    anchor_r=anchor_r, seed=0,
                                    anchor=setup.anchor)
        t_recompute = min(t_recompute, time.perf_counter() - t0)

    agree = _setup_agreement(setup, ref)
    speedup = t_recompute / t_onboard
    assert agree <= ONBOARD_AGREE_BAR, \
        f"onboarded setup drifted {agree:.2e} from full recompute"
    assert speedup >= ONBOARD_SPEEDUP_BAR, \
        f"incremental onboarding only {speedup:.1f}x cheaper than recompute"

    return {
        "layout": {"groups": d, "users_per_group": c, "n_ij": n_ij,
                   "m_raw": M_RAW, "m_tilde": M_TILDE, "anchor_r": anchor_r},
        "traffic": {"requests_per_sweep": n_req, "max_rows": max_rows,
                    "max_batch": 64},
        "t_setup_s": round(t_setup, 4),
        "serve": {
            "t_cold_s": round(t_cold, 4),
            "t_warm_s": round(t_warm, 4),
            "compiles_cold": cc_cold.count,
            "compiles_warm": cc_warm.count,
            "rows_per_s_warm": round(warm_rows / t_warm, 1),
            "p50_latency_ms": round(st["p50_latency_s"] * 1e3, 3),
            "p99_latency_ms": round(st["p99_latency_s"] * 1e3, 3),
            "buckets": st["buckets"],
            "cache": st["cache"],
        },
        "onboard": {
            "n_onboarded": n_onboard,
            "t_incremental_s": round(t_onboard, 5),
            "t_full_recompute_s": round(t_recompute, 4),
            "speedup": round(speedup, 1),
            "agreement_max_rel": agree,
        },
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="small layout + fewer requests (CI)")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    result = run(fast=args.fast)
    result["fast"] = args.fast
    out_path = args.out or os.path.join(
        os.path.dirname(__file__), "..", "results", "BENCH_serve.json")
    os.makedirs(os.path.dirname(out_path), exist_ok=True)
    with open(out_path, "w") as f:
        json.dump(result, f, indent=1)
    s, o = result["serve"], result["onboard"]
    print(f"warm sweep: {s['t_warm_s']}s ({s['rows_per_s_warm']} rows/s), "
          f"compiles cold->warm {s['compiles_cold']}->{s['compiles_warm']}")
    print(f"latency p50 {s['p50_latency_ms']}ms / p99 {s['p99_latency_ms']}ms")
    print(f"onboard: {o['t_incremental_s']}s incremental vs "
          f"{o['t_full_recompute_s']}s recompute = {o['speedup']}x, "
          f"agreement {o['agreement_max_rel']:.2e}")
    print(f"wrote {os.path.abspath(out_path)}")


if __name__ == "__main__":
    main()
