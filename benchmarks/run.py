"""Benchmark entry point — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (fast variants by default; pass
--full for the paper-scale runs recorded in EXPERIMENTS.md)."""
from __future__ import annotations

import argparse
import sys
import time


def _timed(name, fn, *a, **kw):
    t0 = time.perf_counter()
    out = fn(*a, **kw)
    us = (time.perf_counter() - t0) * 1e6
    return name, us, out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="paper-scale runs (minutes on CPU)")
    ap.add_argument("--only", default=None,
                    choices=["exp1", "exp2", "exp3", "comm", "kernels", "noniid"])
    args = ap.parse_args()
    fast = not args.full
    rows = []

    if args.only in (None, "kernels"):
        from benchmarks import kernels_bench
        for name, us, derived in kernels_bench.run(fast=fast):
            rows.append((name, us, derived))

    if args.only in (None, "exp1"):
        from benchmarks import exp1_convergence
        name, us, (res, claims) = _timed("exp1_convergence(fig4)",
                                         exp1_convergence.run, fast=fast)
        rows.append((name, us, f"claims_pass={all(claims.values())}"))

    if args.only in (None, "exp2"):
        from benchmarks import exp2_datasets
        name, us, res = _timed("exp2_datasets(fig5)", exp2_datasets.run,
                               fast=fast)
        ok = all(r["metrics"]["FedDCL"] < r["metrics"]["Local"]
                 if r["task"] == "regression"
                 else r["metrics"]["FedDCL"] > r["metrics"]["Local"]
                 for r in res.values())
        rows.append((name, us, f"feddcl_beats_local_all={ok}"))

    if args.only in (None, "exp3"):
        from benchmarks import exp3_groups
        name, us, out = _timed("exp3_groups(fig6)", exp3_groups.run, fast=fast)
        ds = sorted(out)
        rows.append((name, us,
                     f"feddcl_d{ds[0]}={out[ds[0]]['FedDCL']:.3f};"
                     f"d{ds[-1]}={out[ds[-1]]['FedDCL']:.3f}"))

    if args.only == "noniid":
        from benchmarks import ablation_noniid
        name, us, out = _timed("ablation_noniid(beyond-paper)",
                               ablation_noniid.run, fast=fast)
        rows.append((name, us,
                     f"feddcl_iid={out['iid']['FedDCL']:.3f};"
                     f"dir0.1={out['dir0.1']['FedDCL']:.3f}"))

    if args.only in (None, "comm"):
        from benchmarks import comm_cost
        name, us, (rows_c, table) = _timed("comm_cost(sec3.2)", comm_cost.run,
                                           fast=fast)
        red = rows_c["fedavg_user_bytes_total"] / max(
            rows_c["feddcl_user_bytes_total"], 1)
        rows.append((name, us, f"user_traffic_reduction={red:.1f}x"))

    print("\nname,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
